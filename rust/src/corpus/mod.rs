//! Synthetic document corpus generator.
//!
//! The paper evaluates on proprietary customer documents; we synthesize
//! corpora with controlled size distributions (the experiments sweep
//! document size: 128 B tweets/RSS items up to multi-kB news articles)
//! and realistic entity densities, seeded from the same name/org/location
//! pools the built-in queries' dictionaries use — so query selectivity is
//! realistic by construction. Generation is deterministic per seed.

pub mod pools;

pub use crate::text::Document;

use crate::util::Prng;

/// Shared document framing for the two streaming ingestion paths.
///
/// `repro stream` frames documents as newline-delimited stdin lines and
/// the serving tier (`serve::protocol`) frames them as length-prefixed
/// `Doc{id, bytes}` frames — but both must construct [`Document`]s the
/// same way (same ids-as-given, same UTF-8 validation) or the two paths
/// drift. Both decoders go through this module.
pub mod framing {
    use std::io::{self, BufRead};

    use super::Document;

    /// Why a payload could not become a [`Document`].
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum FramingError {
        /// The payload is not valid UTF-8 (documents are text; spans are
        /// byte offsets into a `str`).
        NotUtf8,
    }

    impl std::fmt::Display for FramingError {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match self {
                FramingError::NotUtf8 => write!(f, "document bytes are not valid UTF-8"),
            }
        }
    }

    impl std::error::Error for FramingError {}

    /// Build a document from a raw byte payload with a caller-supplied
    /// id — the serving tier's `Doc` frame decoder. Empty documents are
    /// legal (the engine produces empty views for them); invalid UTF-8
    /// is a framing error, never a panic.
    pub fn doc_from_bytes(id: u64, bytes: Vec<u8>) -> Result<Document, FramingError> {
        let text = String::from_utf8(bytes).map_err(|_| FramingError::NotUtf8)?;
        Ok(Document::new(id, text))
    }

    /// Frame a line-oriented reader as documents — `repro stream`'s
    /// stdin protocol: one document per line, blank lines skipped, the
    /// document id is the **line number** (so ids stay stable whether or
    /// not blank lines are present).
    pub fn docs_from_lines<B: BufRead>(
        reader: B,
    ) -> impl Iterator<Item = io::Result<Document>> {
        reader
            .lines()
            .enumerate()
            .filter_map(|(i, line)| match line {
                Ok(l) if l.trim().is_empty() => None,
                Ok(l) => Some(Ok(Document::new(i as u64, l))),
                Err(e) => Some(Err(e)),
            })
    }
}

/// Corpus flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    /// Multi-sentence articles with entities, amounts, dates, contacts.
    News,
    /// Short messages (the paper's "Twitter messages and RSS feeds").
    Tweets,
    /// Machine log lines (timestamps, levels, IPs) — semi-structured.
    Logs,
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// Text flavour to generate.
    pub kind: CorpusKind,
    /// Number of documents.
    pub docs: usize,
    /// Target document size in bytes (actual sizes are exact: documents
    /// are padded/trimmed to the target so throughput numbers are
    /// directly comparable to the paper's fixed-size sweeps).
    pub doc_size: usize,
    /// PRNG seed (fixed per flavour unless overridden).
    pub seed: u64,
}

impl CorpusSpec {
    /// News corpus.
    pub fn news(docs: usize, doc_size: usize) -> CorpusSpec {
        CorpusSpec {
            kind: CorpusKind::News,
            docs,
            doc_size,
            seed: 0xC0FFEE,
        }
    }

    /// Tweet-sized corpus.
    pub fn tweets(docs: usize, doc_size: usize) -> CorpusSpec {
        CorpusSpec {
            kind: CorpusKind::Tweets,
            docs,
            doc_size,
            seed: 0x7EE7,
        }
    }

    /// Log corpus.
    pub fn logs(docs: usize, doc_size: usize) -> CorpusSpec {
        CorpusSpec {
            kind: CorpusKind::Logs,
            docs,
            doc_size,
            seed: 0x106,
        }
    }

    /// Override the seed.
    pub fn with_seed(mut self, seed: u64) -> CorpusSpec {
        self.seed = seed;
        self
    }

    /// Generate the corpus.
    pub fn generate(&self) -> Corpus {
        let mut rng = Prng::new(self.seed);
        let docs = (0..self.docs)
            .map(|i| Document::new(i as u64, generate_text(self.kind, self.doc_size, &mut rng)))
            .collect();
        Corpus { docs }
    }
}

/// A generated corpus.
#[derive(Debug, Clone)]
pub struct Corpus {
    /// The generated documents, in id order.
    pub docs: Vec<Document>,
}

impl Corpus {
    /// Total payload bytes.
    pub fn total_bytes(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }

    /// Number of documents.
    pub fn len(&self) -> usize {
        self.docs.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }
}

/// Produce one document of exactly `size` bytes.
fn generate_text(kind: CorpusKind, size: usize, rng: &mut Prng) -> String {
    let mut s = String::with_capacity(size + 128);
    while s.len() < size {
        let sentence = match kind {
            CorpusKind::News => news_sentence(rng),
            CorpusKind::Tweets => tweet_fragment(rng),
            CorpusKind::Logs => log_line(rng),
        };
        s.push_str(&sentence);
        if !s.ends_with(' ') && !s.ends_with('\n') {
            s.push(' ');
        }
    }
    // plain ASCII, so byte-truncation to the exact target size is safe
    s.truncate(size);
    s
}

fn person(rng: &mut Prng) -> String {
    format!(
        "{} {}",
        rng.pick(pools::FIRST_NAMES),
        rng.pick(pools::LAST_NAMES)
    )
}

fn news_sentence(rng: &mut Prng) -> String {
    let p = person(rng);
    let org = *rng.pick(pools::ORGS);
    let loc = *rng.pick(pools::LOCATIONS);
    let verb = *rng.pick(pools::VERBS);
    let noun = *rng.pick(pools::NOUNS);
    match rng.below(8) {
        0 => format!("{p} of {org} {verb} the {noun} in {loc}."),
        1 => format!(
            "{org} announced a ${}.{} million {noun} on {}.",
            rng.range(1, 500),
            rng.below(10),
            date(rng)
        ),
        2 => format!(
            "\"The {noun} is significant,\" said {p}, reachable at {}.",
            phone(rng)
        ),
        3 => format!("{p} joined {org} in {loc} last {}.", rng.pick(pools::MONTHS)),
        4 => format!(
            "Contact {} for details about the {noun} ({org}).",
            email(rng)
        ),
        5 => format!(
            "Shares of {org} ({}) {verb} {}% after the {noun}.",
            ticker(rng),
            rng.range(1, 30),
        ),
        6 => format!("In {loc}, {p} and {} discussed the {noun}.", person(rng)),
        _ => format!(
            "The {noun} report, published {}, cites {p} of {org}.",
            date(rng)
        ),
    }
}

fn tweet_fragment(rng: &mut Prng) -> String {
    let org = *rng.pick(pools::ORGS);
    match rng.below(5) {
        0 => format!("{} just visited {org}! #{}", person(rng), rng.pick(pools::TAGS)),
        1 => format!(
            "wow the {} from {org} is {} http://t.co/{}",
            rng.pick(pools::NOUNS),
            rng.pick(pools::SENTIMENT),
            rng.string_over(b"abcdefghij0123456789", 8)
        ),
        2 => format!("call me at {} about {}", phone(rng), rng.pick(pools::NOUNS)),
        3 => format!(
            "{} {} in {} rn",
            rng.pick(pools::SENTIMENT),
            rng.pick(pools::NOUNS),
            rng.pick(pools::LOCATIONS)
        ),
        _ => format!("@{} did you see the {org} news?", rng.string_over(b"abcdxyz", 6)),
    }
}

fn log_line(rng: &mut Prng) -> String {
    format!(
        "2014-{:02}-{:02}T{:02}:{:02}:{:02} {} svc={} ip={}.{}.{}.{} msg=\"{} {}\"\n",
        rng.range(1, 13),
        rng.range(1, 29),
        rng.below(24),
        rng.below(60),
        rng.below(60),
        rng.pick(&["INFO", "WARN", "ERROR", "DEBUG"]),
        rng.pick(pools::NOUNS),
        rng.range(1, 255),
        rng.below(256),
        rng.below(256),
        rng.below(256),
        rng.pick(pools::VERBS),
        rng.pick(pools::NOUNS),
    )
}

fn phone(rng: &mut Prng) -> String {
    if rng.chance(0.4) {
        format!(
            "({}) {}-{:04}",
            rng.range(200, 999),
            rng.range(200, 999),
            rng.below(10000)
        )
    } else {
        format!("{}-{:04}", rng.range(200, 999), rng.below(10000))
    }
}

fn email(rng: &mut Prng) -> String {
    let user_len = rng.range(3, 9);
    let dom_len = rng.range(3, 7);
    format!(
        "{}@{}.com",
        rng.string_over(b"abcdefghijklmnop", user_len),
        rng.string_over(b"abcdefgh", dom_len)
    )
}

fn date(rng: &mut Prng) -> String {
    format!("2014-{:02}-{:02}", rng.range(1, 13), rng.range(1, 29))
}

fn ticker(rng: &mut Prng) -> String {
    let len = rng.range(2, 5);
    rng.string_over(b"ABCDEFGHIJKLMNOPQRSTUVWXYZ", len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_sizes_and_determinism() {
        let spec = CorpusSpec::news(16, 2048);
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.len(), 16);
        for d in &a.docs {
            assert_eq!(d.len(), 2048);
        }
        for (x, y) in a.docs.iter().zip(&b.docs) {
            assert_eq!(x.text, y.text, "generation must be deterministic");
        }
        assert_eq!(a.total_bytes(), 16 * 2048);
    }

    #[test]
    fn different_seeds_differ() {
        let a = CorpusSpec::news(4, 512).generate();
        let b = CorpusSpec::news(4, 512).with_seed(999).generate();
        assert_ne!(a.docs[0].text, b.docs[0].text);
    }

    #[test]
    fn ascii_and_no_nul() {
        for spec in [
            CorpusSpec::news(8, 1024),
            CorpusSpec::tweets(8, 128),
            CorpusSpec::logs(8, 256),
        ] {
            for d in spec.generate().docs {
                assert!(d.text.is_ascii());
                assert!(!d.text.bytes().any(|b| b == 0), "NUL is reserved");
            }
        }
    }

    #[test]
    fn news_contains_entities() {
        let c = CorpusSpec::news(8, 4096).generate();
        let all: String = c.docs.iter().map(|d| d.text.to_string()).collect();
        assert!(pools::ORGS.iter().any(|o| all.contains(o)));
        assert!(pools::LOCATIONS.iter().any(|l| all.contains(l)));
        assert!(pools::FIRST_NAMES.iter().any(|n| all.contains(n)));
    }

    #[test]
    fn tweets_are_small() {
        let c = CorpusSpec::tweets(32, 128).generate();
        assert!(c.docs.iter().all(|d| d.len() == 128));
    }

    #[test]
    fn logs_look_like_logs() {
        let c = CorpusSpec::logs(4, 512).generate();
        assert!(c.docs[0].text.contains("svc="));
    }

    #[test]
    fn framing_lines_number_by_line_and_skip_blanks() {
        let input = "first doc\n\nsecond doc\n   \nthird\n";
        let docs: Vec<_> = framing::docs_from_lines(std::io::Cursor::new(input))
            .collect::<std::io::Result<_>>()
            .unwrap();
        assert_eq!(docs.len(), 3);
        assert_eq!((docs[0].id, &*docs[0].text), (0, "first doc"));
        assert_eq!((docs[1].id, &*docs[1].text), (2, "second doc"));
        assert_eq!((docs[2].id, &*docs[2].text), (4, "third"));
    }

    #[test]
    fn framing_bytes_validates_utf8() {
        let d = framing::doc_from_bytes(9, b"ok text".to_vec()).unwrap();
        assert_eq!((d.id, &*d.text), (9, "ok text"));
        assert!(framing::doc_from_bytes(0, Vec::new()).unwrap().is_empty());
        assert_eq!(
            framing::doc_from_bytes(1, vec![0xff, 0xfe]).unwrap_err(),
            framing::FramingError::NotUtf8
        );
    }
}
