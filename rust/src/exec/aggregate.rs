//! Corpus-level aggregation: the merge-friendly partial behind
//! [`OpKind::GroupAgg`](crate::aog::OpKind::GroupAgg) and the bounded
//! top-k selection behind [`OpKind::TopK`](crate::aog::OpKind::TopK).
//!
//! Per-document execution treats each document as a **corpus of one**:
//! the operator absorbs the document's rows into a fresh [`AggPartial`]
//! and emits `finish()` immediately, so `run_doc` stays a pure
//! per-document function and DocResult/serve/golden outputs remain
//! byte-identical across execution routes. The executor additionally
//! exports the per-document partial; the session coordinator merges one
//! partial per worker and finishes the merged state once at
//! `Session::finish()` — see [`AggPartial::merge`], which is associative
//! and commutative, so worker count, partition mode and arrival order
//! cannot change the corpus-level result.
//!
//! State lives in ordinary heap `HashMap`s, **not** in the columnar
//! arena: arena buffers are per-document and return to their origin shard
//! when a batch drops, while aggregate state must outlive every document
//! and cross worker threads at merge time. Only the `finish()` output
//! rematerializes as a [`TupleBatch`].

use std::cmp::Ordering;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::aog::{AggCol, EvalCtx, Expr, Schema, Tuple, Value};

use super::batch::TupleBatch;
use super::operators::cmp_values;

/// One group-key cell, hashable and totally ordered. A group column is
/// schema-typed (Text, Integer or Boolean — enforced by
/// `derive_schema`), so cross-variant comparisons only arise against
/// `Null`, which the variant order sorts last (matching
/// [`cmp_values`]' nulls-last convention).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KeyPart {
    /// An integer key cell.
    Int(i64),
    /// A boolean key cell.
    Bool(bool),
    /// A text key cell (`Arc<str>` hashes/orders by bytes).
    Str(Arc<str>),
    /// A null key cell (sorts last).
    Null,
}

impl KeyPart {
    fn from_value(v: &Value) -> KeyPart {
        match v {
            Value::Int(n) => KeyPart::Int(*n),
            Value::Bool(b) => KeyPart::Bool(*b),
            Value::Str(s) => KeyPart::Str(s.clone()),
            Value::Null => KeyPart::Null,
            other => panic!(
                "non-groupable key value {other:?} — schema derivation admits only \
                 Text/Integer/Boolean keys"
            ),
        }
    }

    fn to_value(&self) -> Value {
        match self {
            KeyPart::Int(n) => Value::Int(*n),
            KeyPart::Bool(b) => Value::Bool(*b),
            KeyPart::Str(s) => Value::Str(s.clone()),
            KeyPart::Null => Value::Null,
        }
    }
}

/// Accumulator of one group.
#[derive(Debug, Clone, Copy, Default)]
struct Acc {
    /// Total matching rows (`Count()`).
    count: u64,
    /// Documents contributing at least one row (`CountDocs()`).
    docs: u64,
}

/// Mergeable hash-aggregate state for one `GroupAgg` node.
///
/// Lifecycle: `new` → any number of [`absorb_doc`](AggPartial::absorb_doc)
/// / [`merge`](AggPartial::merge) calls, in any order and sharding →
/// [`finish`](AggPartial::finish). Merge is associative and commutative
/// (both counters are sums), and `finish` sorts groups by key, so the
/// output is a pure function of the absorbed multiset of documents.
#[derive(Debug, Clone)]
pub struct AggPartial {
    /// Output column spec, in select-list order.
    cols: Vec<(String, AggCol)>,
    /// The `GroupAgg` node's output schema (for `finish`).
    schema: Schema,
    /// Input column indices of the keys, in key order.
    key_idx: Vec<usize>,
    groups: HashMap<Vec<KeyPart>, Acc>,
}

impl AggPartial {
    /// Empty state for a `GroupAgg` node's column spec and output schema.
    pub fn new(cols: &[(String, AggCol)], schema: &Schema) -> AggPartial {
        let key_idx = cols
            .iter()
            .filter_map(|(_, c)| match c {
                AggCol::Key(j) => Some(*j),
                _ => None,
            })
            .collect();
        AggPartial {
            cols: cols.to_vec(),
            schema: schema.clone(),
            key_idx,
            groups: HashMap::new(),
        }
    }

    /// Absorb all rows of ONE document. `Count` advances per row;
    /// `CountDocs` advances at most once per group per call, which is
    /// what makes it the document-frequency aggregate.
    pub fn absorb_doc(&mut self, rows: &[Tuple]) {
        let mut seen: HashSet<Vec<KeyPart>> = HashSet::new();
        for row in rows {
            let key: Vec<KeyPart> = self
                .key_idx
                .iter()
                .map(|&j| KeyPart::from_value(&row[j]))
                .collect();
            let acc = self.groups.entry(key.clone()).or_default();
            acc.count += 1;
            if seen.insert(key) {
                acc.docs += 1;
            }
        }
    }

    /// Fold another partial into this one. Associative and commutative:
    /// both counters are plain sums over disjoint document sets.
    pub fn merge(&mut self, other: &AggPartial) {
        for (key, acc) in &other.groups {
            let mine = self.groups.entry(key.clone()).or_default();
            mine.count += acc.count;
            mine.docs += acc.docs;
        }
    }

    /// Number of distinct groups seen so far.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// True when no rows were absorbed.
    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Materialize the final aggregate: one row per group, sorted by key
    /// ascending, columns in the node's select-list order.
    pub fn finish(&self) -> TupleBatch {
        let mut keys: Vec<&Vec<KeyPart>> = self.groups.keys().collect();
        keys.sort();
        let mut rows: Vec<Tuple> = Vec::with_capacity(keys.len());
        for key in keys {
            let acc = &self.groups[key];
            let mut ki = 0usize;
            let row: Tuple = self
                .cols
                .iter()
                .map(|(_, c)| match c {
                    AggCol::Key(_) => {
                        let v = key[ki].to_value();
                        ki += 1;
                        v
                    }
                    AggCol::Count => Value::Int(acc.count as i64),
                    AggCol::CountDocs => Value::Int(acc.docs as i64),
                })
                .collect();
            rows.push(row);
        }
        TupleBatch::from_rows(&self.schema, &rows)
    }
}

/// Evaluate a `GroupAgg` node on one document's input batch: absorb into
/// a fresh partial, return the corpus-of-one `finish()` output *and* the
/// partial itself (for the session's cross-document merge). Both
/// execution strategies call this one implementation, so their outputs
/// are byte-identical by construction.
pub fn group_agg_doc(
    cols: &[(String, AggCol)],
    schema: &Schema,
    input: &TupleBatch,
) -> (TupleBatch, AggPartial) {
    let mut partial = AggPartial::new(cols, schema);
    partial.absorb_doc(&input.to_tuples());
    (partial.finish(), partial)
}

/// Score descending with nulls last.
fn cmp_score_desc(a: &Value, b: &Value) -> Ordering {
    match (matches!(a, Value::Null), matches!(b, Value::Null)) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => cmp_values(b, a),
    }
}

/// Bounded top-k over an aggregate batch: score every row, keep the `k`
/// best by score descending, break ties by the input cells ascending
/// (text keys compare by bytes) — an explicit total order, so the result
/// does not depend on the input's arrival order. Output rows carry a
/// trailing score column (`out_schema` is the `TopK` node's schema).
pub fn top_k(
    input: &TupleBatch,
    k: usize,
    score: &Expr,
    out_schema: &Schema,
    ctx: &EvalCtx<'_>,
) -> TupleBatch {
    let mut scored: Vec<(Value, Tuple)> = input
        .to_tuples()
        .into_iter()
        .map(|row| (score.eval(&row, ctx), row))
        .collect();
    scored.sort_by(|(sa, ra), (sb, rb)| {
        cmp_score_desc(sa, sb).then_with(|| {
            for (x, y) in ra.iter().zip(rb.iter()) {
                let o = cmp_values(x, y);
                if o != Ordering::Equal {
                    return o;
                }
            }
            Ordering::Equal
        })
    });
    scored.truncate(k);
    let rows: Vec<Tuple> = scored
        .into_iter()
        .map(|(s, mut row)| {
            row.push(s);
            row
        })
        .collect();
    TupleBatch::from_rows(out_schema, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::{Field, FieldType};
    use crate::text::Tokenizer;

    fn spec() -> (Vec<(String, AggCol)>, Schema) {
        let cols = vec![
            ("term".to_string(), AggCol::Key(0)),
            ("n".to_string(), AggCol::Count),
            ("docs".to_string(), AggCol::CountDocs),
        ];
        let schema = Schema {
            fields: vec![
                Field {
                    name: "term".into(),
                    ty: FieldType::Str,
                },
                Field {
                    name: "n".into(),
                    ty: FieldType::Int,
                },
                Field {
                    name: "docs".into(),
                    ty: FieldType::Int,
                },
            ],
        };
        (cols, schema)
    }

    fn doc_rows(terms: &[&str]) -> Vec<Tuple> {
        terms.iter().map(|t| vec![Value::Str((*t).into())]).collect()
    }

    #[test]
    fn count_and_count_docs_differ() {
        let (cols, schema) = spec();
        let mut p = AggPartial::new(&cols, &schema);
        p.absorb_doc(&doc_rows(&["ibm", "ibm", "acme"]));
        p.absorb_doc(&doc_rows(&["ibm"]));
        let rows = p.finish().to_tuples();
        // sorted by key: acme, ibm
        assert_eq!(rows[0][0], Value::Str("acme".into()));
        assert_eq!(rows[0][1], Value::Int(1));
        assert_eq!(rows[0][2], Value::Int(1));
        assert_eq!(rows[1][0], Value::Str("ibm".into()));
        assert_eq!(rows[1][1], Value::Int(3)); // three mentions
        assert_eq!(rows[1][2], Value::Int(2)); // two documents
    }

    #[test]
    fn merge_matches_sequential_absorb() {
        let (cols, schema) = spec();
        let docs: Vec<Vec<Tuple>> = vec![
            doc_rows(&["a", "b", "a"]),
            doc_rows(&["b"]),
            doc_rows(&["c", "a"]),
            doc_rows(&[]),
        ];
        let mut all = AggPartial::new(&cols, &schema);
        for d in &docs {
            all.absorb_doc(d);
        }
        // shard docs 2 ways, merge in both orders
        let mut left = AggPartial::new(&cols, &schema);
        left.absorb_doc(&docs[0]);
        left.absorb_doc(&docs[1]);
        let mut right = AggPartial::new(&cols, &schema);
        right.absorb_doc(&docs[2]);
        right.absorb_doc(&docs[3]);
        let mut lr = left.clone();
        lr.merge(&right);
        let mut rl = right.clone();
        rl.merge(&left);
        let want = all.finish().to_tuples();
        assert_eq!(lr.finish().to_tuples(), want);
        assert_eq!(rl.finish().to_tuples(), want);
        assert_eq!(all.num_groups(), 3);
        assert!(!all.is_empty());
    }

    #[test]
    fn top_k_orders_by_score_then_key_bytes() {
        let (cols, schema) = spec();
        let mut p = AggPartial::new(&cols, &schema);
        // zz and aa tie at 2 mentions; mid has 3
        p.absorb_doc(&doc_rows(&["zz", "zz", "aa", "mid"]));
        p.absorb_doc(&doc_rows(&["aa", "mid", "mid"]));
        let agg = p.finish();
        let mut out_schema = schema.clone();
        out_schema.fields.push(Field {
            name: "score".into(),
            ty: FieldType::Int,
        });
        let tokens = Tokenizer::standard().tokenize("");
        let ctx = EvalCtx {
            text: "",
            tokens: &tokens,
        };
        let rows = top_k(&agg, 2, &Expr::Col(1), &out_schema, &ctx).to_tuples();
        assert_eq!(rows.len(), 2);
        // mid (3) first, then the aa/zz tie resolves by term bytes: aa
        assert_eq!(rows[0][0], Value::Str("mid".into()));
        assert_eq!(rows[0][3], Value::Int(3));
        assert_eq!(rows[1][0], Value::Str("aa".into()));
        assert_eq!(rows[1][3], Value::Int(2));
        // k larger than the group count keeps everything
        let all = top_k(&agg, 99, &Expr::Col(1), &out_schema, &ctx);
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn empty_partial_finishes_empty_with_schema() {
        let (cols, schema) = spec();
        let p = AggPartial::new(&cols, &schema);
        let b = p.finish();
        assert_eq!(b.len(), 0);
        assert_eq!(b.num_columns(), 3);
    }
}
