//! Software execution of operator graphs, document-per-thread, with the
//! per-operator profiler that produces the paper's Fig 4 — and the typed
//! result surface ([`ViewHandle`], [`ViewCatalog`], [`DocResult`]) that
//! the streaming [`Session`](crate::coordinator::Session) API is built on.
//!
//! Execution is **columnar**: operators consume and produce
//! [`TupleBatch`]es (one typed buffer per column, recycled through the
//! return-to-origin sharded arena) instead of `Vec<Tuple>` rows — see
//! [`batch`] for the layout and arena lifecycle. The seed's row-at-a-time
//! pipeline survives behind [`ExecStrategy::LegacyRows`] as the reference
//! baseline for differential tests and the old-vs-new benchmark; rows
//! themselves survive only at the API boundary, where [`DocResult`]
//! converts lazily on first access.

pub mod aggregate;
pub mod batch;
pub mod operators;
pub mod profiler;

pub use aggregate::{group_agg_doc, top_k, AggPartial, KeyPart};
pub use batch::{ArenaId, ArenaStats, ColumnData, TupleBatch, TupleRef};
pub use operators::{cmp_tuples, cmp_values};
pub use profiler::{Profile, Profiler};

use std::collections::HashMap;
use std::ops::Index;
use std::sync::{Arc, OnceLock};

use crate::aog::{EvalCtx, Graph, NodeId, OpKind, Schema, Tuple};
use crate::text::{Document, TokenIndex, Tokenizer};

/// Which executor pipeline evaluates the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecStrategy {
    /// Columnar [`TupleBatch`] execution over per-thread arenas — the
    /// production hot path (default).
    #[default]
    Columnar,
    /// The seed's row-at-a-time `Vec<Tuple>` pipeline (one heap
    /// allocation per tuple per operator). Kept as the reference baseline
    /// for the columnar differential suite and `repro bench`'s old-vs-new
    /// measurement.
    LegacyRows,
}

impl ExecStrategy {
    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            ExecStrategy::Columnar => "columnar",
            ExecStrategy::LegacyRows => "legacy-rows",
        }
    }

    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Option<ExecStrategy> {
        match s {
            "columnar" => Some(ExecStrategy::Columnar),
            "legacy" | "legacy-rows" | "rows" => Some(ExecStrategy::LegacyRows),
            _ => None,
        }
    }
}

/// Pluggable executor for `SubgraphExec` nodes (the hardware-offloaded
/// subgraphs in a partitioned supergraph). The software fallback
/// re-executes the subgraph body in software; the accelerator
/// implementation ships the document through the communication interface.
pub trait SubgraphRunner: Send + Sync {
    /// Run subgraph `id` on `doc` with software-computed tuple streams
    /// `ext` (one per `ExtInput` slot), returning the tuples of output
    /// `output_idx`. Implementations should cache per-(doc, subgraph) so
    /// multi-output subgraphs execute once per document.
    fn run(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
    ) -> Vec<Tuple>;

    /// Columnar form of [`SubgraphRunner::run`]: same contract, with the
    /// external streams and the result as [`TupleBatch`]es (`schema` is
    /// the output's compile-time schema). The default shim round-trips
    /// through rows, so existing implementations keep working; the
    /// built-in runners override it to stay columnar end to end.
    fn run_batch(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        schema: &Schema,
    ) -> TupleBatch {
        let ext_rows: Vec<Vec<Tuple>> = ext.iter().map(|b| b.to_tuples()).collect();
        let ext_refs: Vec<&[Tuple]> = ext_rows.iter().map(|v| v.as_slice()).collect();
        TupleBatch::from_rows(schema, &self.run(id, output_idx, doc, tokens, &ext_refs))
    }
}

/// A compile-time-resolved reference to one output view: stable index into
/// the executed graph's output list, plus the view's name and schema.
///
/// Handles are resolved once (via [`ViewCatalog::resolve`] or
/// [`Engine::view`](crate::coordinator::Engine::view)) and then used for
/// O(1), typo-proof access into every [`DocResult`] the same engine
/// produces — replacing the stringly-typed `DocOutput.views` HashMap.
#[derive(Debug, Clone)]
pub struct ViewHandle {
    index: usize,
    name: Arc<str>,
    schema: Schema,
}

impl ViewHandle {
    /// The view's name as written in the AQL `output view` statement.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The view's tuple schema (column names and types).
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Positional index of this view in the engine's output list.
    pub fn index(&self) -> usize {
        self.index
    }
}

/// The output views of one compiled graph, in output order. Built once per
/// [`Executor`]; every [`DocResult`] carries a shared reference.
#[derive(Debug)]
pub struct ViewCatalog {
    views: Vec<ViewHandle>,
    /// name → output index, built once so per-lookup resolution (Session
    /// subscriptions, `Engine::view`, `DocResult::by_name`) is O(1)
    /// instead of a linear scan over the catalog.
    by_name: HashMap<Arc<str>, usize>,
}

impl ViewCatalog {
    /// Build the catalog from a graph's registered outputs.
    pub fn for_graph(g: &Graph) -> ViewCatalog {
        let views: Vec<ViewHandle> = g
            .outputs
            .iter()
            .enumerate()
            .map(|(index, (name, node))| ViewHandle {
                index,
                name: name.as_str().into(),
                schema: g.nodes[*node].schema.clone(),
            })
            .collect();
        let mut by_name = HashMap::with_capacity(views.len());
        for (i, h) in views.iter().enumerate() {
            // first registration wins, matching the old linear-scan find
            by_name.entry(h.name.clone()).or_insert(i);
        }
        ViewCatalog { views, by_name }
    }

    /// Resolve a view by name (O(1)).
    pub fn resolve(&self, name: &str) -> Option<&ViewHandle> {
        self.by_name.get(name).map(|&i| &self.views[i])
    }

    /// All view handles, in output order.
    pub fn handles(&self) -> &[ViewHandle] {
        &self.views
    }

    /// Number of output views.
    pub fn len(&self) -> usize {
        self.views.len()
    }

    /// True when the graph registers no output views.
    pub fn is_empty(&self) -> bool {
        self.views.is_empty()
    }

    /// Comma-separated view names (for error messages).
    fn names(&self) -> String {
        self.views
            .iter()
            .map(|h| &*h.name)
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Output of one document evaluation: one result per output view,
/// positionally indexed and paired with the shared [`ViewCatalog`].
///
/// Both layouts are lazy and symmetric: the columnar executor constructs
/// from [`TupleBatch`]es and materializes `Vec<Tuple>` rows on first
/// row-shaped access (`result[&handle]`, `result["Name"]`,
/// [`DocResult::views`]); the legacy pipeline constructs from rows and
/// mirrors batches only if [`DocResult::view_batch`]/[`DocResult::batches`]
/// are actually asked for — so neither strategy pays for the layout it
/// doesn't use (the old-vs-new benchmark depends on this symmetry).
/// Counting ([`DocResult::total_tuples`], [`DocResult::num_views`]) reads
/// whichever layout exists.
#[derive(Debug)]
pub struct DocResult {
    doc_id: u64,
    catalog: Arc<ViewCatalog>,
    batches: OnceLock<Vec<TupleBatch>>,
    rows: OnceLock<Vec<Vec<Tuple>>>,
    /// Per-view row caches for single-view access (`view`, `by_name`,
    /// session subscriptions): converting one subscribed view must not
    /// materialize every other view of a wide catalog result.
    row_cells: OnceLock<Box<[OnceLock<Vec<Tuple>>]>>,
}

impl Clone for DocResult {
    fn clone(&self) -> DocResult {
        let batches = OnceLock::new();
        if let Some(b) = self.batches.get() {
            let _ = batches.set(b.clone());
        }
        let rows = OnceLock::new();
        if let Some(r) = self.rows.get() {
            let _ = rows.set(r.clone());
        }
        DocResult {
            doc_id: self.doc_id,
            catalog: self.catalog.clone(),
            batches,
            rows,
            // per-view caches are cheap to rebuild; don't clone them
            row_cells: OnceLock::new(),
        }
    }
}

impl DocResult {
    /// Wrap per-view batches (the columnar executor's output path).
    pub(crate) fn from_batches(
        doc_id: u64,
        catalog: Arc<ViewCatalog>,
        views: Vec<TupleBatch>,
    ) -> DocResult {
        let batches = OnceLock::new();
        let _ = batches.set(views);
        DocResult {
            doc_id,
            catalog,
            batches,
            rows: OnceLock::new(),
            row_cells: OnceLock::new(),
        }
    }

    /// Wrap legacy per-view rows (the [`ExecStrategy::LegacyRows`] path).
    pub(crate) fn from_rows(
        doc_id: u64,
        catalog: Arc<ViewCatalog>,
        views: Vec<Vec<Tuple>>,
    ) -> DocResult {
        let rows = OnceLock::new();
        let _ = rows.set(views);
        DocResult {
            doc_id,
            catalog,
            batches: OnceLock::new(),
            rows,
            row_cells: OnceLock::new(),
        }
    }

    /// Id of the document this result belongs to.
    pub fn doc_id(&self) -> u64 {
        self.doc_id
    }

    fn materialize_rows(&self) -> &Vec<Vec<Tuple>> {
        self.rows.get_or_init(|| {
            self.batches
                .get()
                .expect("one layout is always populated at construction")
                .iter()
                .map(|b| b.to_tuples())
                .collect()
        })
    }

    /// Rows of ONE view, converting only that view — the single-view
    /// access path (subscriptions, `result[&handle]`) must not
    /// materialize every other view of the result.
    fn view_rows(&self, index: usize) -> &Vec<Tuple> {
        if let Some(rows) = self.rows.get() {
            return &rows[index];
        }
        let batches = self
            .batches
            .get()
            .expect("one layout is always populated at construction");
        let cells = self
            .row_cells
            .get_or_init(|| (0..batches.len()).map(|_| OnceLock::new()).collect());
        cells[index].get_or_init(|| batches[index].to_tuples())
    }

    fn materialize_batches(&self) -> &Vec<TupleBatch> {
        self.batches.get_or_init(|| {
            let rows = self
                .rows
                .get()
                .expect("one layout is always populated at construction");
            self.catalog
                .handles()
                .iter()
                .zip(rows)
                .map(|(h, view)| TupleBatch::from_rows(h.schema(), view))
                .collect()
        })
    }

    fn check_handle(&self, handle: &ViewHandle) {
        match self.catalog.views.get(handle.index) {
            Some(own) if own.name == handle.name && own.schema == handle.schema => {}
            _ => panic!(
                "view handle '{}' does not belong to this engine (outputs: {})",
                handle.name,
                self.catalog.names()
            ),
        }
    }

    /// Tuples of the view behind `handle` (materializes rows lazily).
    ///
    /// Panics if the handle was resolved from a *different* engine whose
    /// output list does not match — same name AND schema at the same
    /// position (handles are engine-specific).
    pub fn view(&self, handle: &ViewHandle) -> &Vec<Tuple> {
        self.check_handle(handle);
        self.view_rows(handle.index)
    }

    /// Columnar batch of the view behind `handle`.
    pub fn view_batch(&self, handle: &ViewHandle) -> &TupleBatch {
        self.check_handle(handle);
        &self.materialize_batches()[handle.index]
    }

    /// Tuple count of the view behind `handle` — reads whichever layout
    /// already exists, never converts (the counting path per-query
    /// subscriptions use).
    pub fn view_len(&self, handle: &ViewHandle) -> usize {
        self.check_handle(handle);
        match self.batches.get() {
            Some(b) => b[handle.index].len(),
            None => self.materialize_rows()[handle.index].len(),
        }
    }

    /// Tuples of the view named `name`, if it exists.
    pub fn by_name(&self, name: &str) -> Option<&Vec<Tuple>> {
        self.catalog.resolve(name).map(|h| self.view_rows(h.index))
    }

    /// Raw per-view tuple vectors, in output (catalog) order.
    pub fn views(&self) -> &[Vec<Tuple>] {
        self.materialize_rows()
    }

    /// Raw per-view columnar batches, in output (catalog) order.
    pub fn batches(&self) -> &[TupleBatch] {
        self.materialize_batches()
    }

    /// Consume into the per-view batches (output order) — the accelerator
    /// post-stage's zero-conversion path.
    pub(crate) fn into_batches(self) -> Vec<TupleBatch> {
        self.materialize_batches();
        self.batches
            .into_inner()
            .expect("materialize_batches just populated it")
    }

    /// Iterate `(handle, tuples)` pairs in output order.
    pub fn iter(&self) -> impl Iterator<Item = (&ViewHandle, &Vec<Tuple>)> {
        self.catalog.views.iter().zip(self.materialize_rows().iter())
    }

    /// The catalog describing the views of this result.
    pub fn catalog(&self) -> &ViewCatalog {
        &self.catalog
    }

    /// Number of output views.
    pub fn num_views(&self) -> usize {
        match self.batches.get() {
            Some(b) => b.len(),
            None => self.materialize_rows().len(),
        }
    }

    /// Total tuple count across views — reads whichever layout already
    /// exists, never converts.
    pub fn total_tuples(&self) -> usize {
        match self.batches.get() {
            Some(b) => b.iter().map(|v| v.len()).sum(),
            None => self.materialize_rows().iter().map(|v| v.len()).sum(),
        }
    }

    /// Convert into the legacy stringly-typed [`DocOutput`] (allocates one
    /// `HashMap` entry per view). Migration shim only.
    #[allow(deprecated)]
    pub fn into_output(self) -> DocOutput {
        let names: Vec<String> = self
            .catalog
            .views
            .iter()
            .map(|h| h.name.to_string())
            .collect();
        self.materialize_rows();
        let views = self
            .rows
            .into_inner()
            .expect("materialize_rows just populated it");
        DocOutput {
            views: names.into_iter().zip(views).collect(),
        }
    }
}

impl Index<&ViewHandle> for DocResult {
    type Output = Vec<Tuple>;

    fn index(&self, handle: &ViewHandle) -> &Vec<Tuple> {
        self.view(handle)
    }
}

impl Index<&str> for DocResult {
    type Output = Vec<Tuple>;

    fn index(&self, name: &str) -> &Vec<Tuple> {
        match self.by_name(name) {
            Some(t) => t,
            None => panic!(
                "no output view named '{name}' (outputs: {})",
                self.catalog.names()
            ),
        }
    }
}

/// Legacy output of one document evaluation: tuples per output view, keyed
/// by view name.
#[deprecated(
    note = "stringly-typed result surface; use DocResult with ViewHandle \
            (resolve handles via Engine::view / ViewCatalog::resolve)"
)]
#[derive(Debug, Clone, Default)]
pub struct DocOutput {
    /// Tuples per output view, keyed by view name.
    pub views: HashMap<String, Vec<Tuple>>,
}

#[allow(deprecated)]
impl DocOutput {
    /// Total tuple count across views.
    pub fn total_tuples(&self) -> usize {
        self.views.values().map(|v| v.len()).sum()
    }
}

/// Per-document corpus-aggregation deltas: one [`AggPartial`] per
/// `GroupAgg` node, keyed by node id. A worker keeps one `CorpusAgg` and
/// merges each successful document's delta into it; the session merges
/// the per-worker states at finish. Merging is associative and
/// commutative, so worker count and arrival order cannot change the
/// final corpus-level result.
#[derive(Debug, Clone, Default)]
pub struct CorpusAgg {
    partials: HashMap<NodeId, AggPartial>,
}

impl CorpusAgg {
    /// Fold another collector into this one (per-node partial merge).
    pub fn merge(&mut self, other: &CorpusAgg) {
        for (id, p) in &other.partials {
            match self.partials.get_mut(id) {
                Some(mine) => mine.merge(p),
                None => {
                    self.partials.insert(*id, p.clone());
                }
            }
        }
    }

    /// True when no aggregate state has been collected.
    pub fn is_empty(&self) -> bool {
        self.partials.is_empty()
    }
}

/// Corpus-level result of one aggregated output view, materialized at
/// `Session::finish()` from the merged worker partials. Row-shaped (not
/// arena-backed) because it outlives every per-document arena scope and
/// travels inside `RunReport`.
#[derive(Debug, Clone)]
pub struct CorpusResult {
    /// Qualified output-view name (e.g. `t6.TopEntities`).
    pub view: String,
    /// The view's schema.
    pub schema: Schema,
    /// Finished aggregate rows (groups sorted by key; top-k by score
    /// descending).
    pub rows: Vec<Tuple>,
}

/// Evaluates a graph over documents. Stateless w.r.t. documents, so one
/// instance is shared by all worker threads (each thread recycles column
/// buffers through its home shard of the [`batch`] arena).
pub struct Executor {
    graph: Arc<Graph>,
    profiler: Arc<Profiler>,
    subgraph_runner: Option<Arc<dyn SubgraphRunner>>,
    live: Vec<bool>,
    catalog: Arc<ViewCatalog>,
    strategy: ExecStrategy,
    /// `ExtInput` slot → schema, for converting row-shaped injections at
    /// the API boundary.
    ext_schemas: Vec<Option<Schema>>,
}

impl Executor {
    /// Build an executor (columnar strategy). `profiler` may be
    /// [`Profiler::disabled`].
    pub fn new(graph: Arc<Graph>, profiler: Arc<Profiler>) -> Executor {
        let live = graph.live_nodes();
        let catalog = Arc::new(ViewCatalog::for_graph(&graph));
        let ext_schemas = graph.ext_input_schemas();
        Executor {
            graph,
            profiler,
            subgraph_runner: None,
            live,
            catalog,
            strategy: ExecStrategy::Columnar,
            ext_schemas,
        }
    }

    /// Select the executor pipeline (columnar by default).
    pub fn with_strategy(mut self, strategy: ExecStrategy) -> Executor {
        self.strategy = strategy;
        self
    }

    /// Attach a subgraph runner (required if the graph contains
    /// `SubgraphExec` nodes).
    pub fn with_subgraph_runner(mut self, r: Arc<dyn SubgraphRunner>) -> Executor {
        self.subgraph_runner = Some(r);
        self
    }

    /// The graph being executed.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The attached profiler.
    pub fn profiler(&self) -> &Arc<Profiler> {
        &self.profiler
    }

    /// The output-view catalog of the executed graph.
    pub fn catalog(&self) -> &Arc<ViewCatalog> {
        &self.catalog
    }

    /// The executor pipeline in use.
    pub fn strategy(&self) -> ExecStrategy {
        self.strategy
    }

    /// Evaluate all output views on one document.
    pub fn run_doc(&self, doc: &Document) -> DocResult {
        let tokens = Tokenizer::standard().tokenize(&doc.text);
        self.run_doc_batched(doc, &tokens, &[], &HashMap::new())
    }

    /// Evaluate one document AND export its corpus-aggregation delta (one
    /// [`AggPartial`] per `GroupAgg` node). The [`DocResult`] is identical
    /// to [`Executor::run_doc`]'s — aggregated views carry the corpus-of-one
    /// output for this document — while the returned [`CorpusAgg`] feeds
    /// the session's cross-document merge. For graphs without aggregate
    /// nodes the collector comes back empty.
    pub fn run_doc_agg(&self, doc: &Document) -> (DocResult, CorpusAgg) {
        let tokens = Tokenizer::standard().tokenize(&doc.text);
        let mut agg = CorpusAgg::default();
        let result = match self.strategy {
            ExecStrategy::Columnar => {
                self.run_columnar(doc, &tokens, &[], &HashMap::new(), Some(&mut agg))
            }
            ExecStrategy::LegacyRows => {
                self.run_legacy(doc, &tokens, &[], &HashMap::new(), Some(&mut agg))
            }
        };
        (result, agg)
    }

    /// Materialize every aggregated output view from merged corpus state:
    /// `GroupAgg` outputs finish their partial; `TopK` outputs finish the
    /// upstream `GroupAgg` partial and apply the bounded top-k selection.
    /// Outputs whose partial is missing (e.g. zero successful documents)
    /// come back as empty, schema-correct rows. Non-aggregated outputs are
    /// skipped — they stream per document.
    pub fn corpus_results(&self, agg: &CorpusAgg) -> Vec<CorpusResult> {
        let mut out = Vec::new();
        for (name, id) in &self.graph.outputs {
            let node = &self.graph.nodes[*id];
            let batch = match &node.kind {
                OpKind::GroupAgg { cols } => match agg.partials.get(id) {
                    Some(p) => p.finish(),
                    None => AggPartial::new(cols, &node.schema).finish(),
                },
                OpKind::TopK { k, score } => {
                    let input = &self.graph.nodes[node.inputs[0]];
                    let finished = match (&input.kind, agg.partials.get(&input.id)) {
                        (OpKind::GroupAgg { .. }, Some(p)) => p.finish(),
                        (OpKind::GroupAgg { cols }, None) => {
                            AggPartial::new(cols, &input.schema).finish()
                        }
                        // TopK over a non-aggregate input has no corpus
                        // state; it streamed per document
                        _ => continue,
                    };
                    // the aggregate schema carries no spans, so no
                    // text-touching function can appear in `score` — an
                    // empty evaluation context is safe
                    let tokens = Tokenizer::standard().tokenize("");
                    let ctx = EvalCtx {
                        text: "",
                        tokens: &tokens,
                    };
                    aggregate::top_k(&finished, *k, score, &node.schema, &ctx)
                }
                _ => continue,
            };
            out.push(CorpusResult {
                view: name.clone(),
                schema: node.schema.clone(),
                rows: batch.to_tuples(),
            });
        }
        out
    }

    /// Evaluate with injected external inputs (`ExtInput` slots) and node
    /// overrides (node id → precomputed tuples), both row-shaped — the
    /// legacy boundary. Columnar callers (the accelerator post-stage)
    /// should use [`Executor::run_doc_batched`].
    pub fn run_doc_with(
        &self,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
        overrides: &HashMap<NodeId, Vec<Tuple>>,
    ) -> DocResult {
        match self.strategy {
            ExecStrategy::LegacyRows => self.run_legacy(doc, tokens, ext, overrides, None),
            ExecStrategy::Columnar => {
                let ext_b: Vec<TupleBatch> = ext
                    .iter()
                    .enumerate()
                    .map(|(slot, rows)| match self.ext_schemas.get(slot) {
                        Some(Some(schema)) => TupleBatch::from_rows(schema, rows),
                        // slot provided but referenced by no ExtInput
                        // node: keep positions aligned with a placeholder
                        _ => TupleBatch::empty(),
                    })
                    .collect();
                let ext_refs: Vec<&TupleBatch> = ext_b.iter().collect();
                let ov_b: HashMap<NodeId, TupleBatch> = overrides
                    .iter()
                    .map(|(&id, rows)| {
                        (id, TupleBatch::from_rows(&self.graph.nodes[id].schema, rows))
                    })
                    .collect();
                self.run_columnar(doc, tokens, &ext_refs, &ov_b, None)
            }
        }
    }

    /// Columnar evaluation with batch-shaped external inputs and node
    /// overrides — the zero-conversion entry the accelerator post-stage
    /// and the software subgraph runner use.
    pub fn run_doc_batched(
        &self,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        overrides: &HashMap<NodeId, TupleBatch>,
    ) -> DocResult {
        match self.strategy {
            ExecStrategy::Columnar => self.run_columnar(doc, tokens, ext, overrides, None),
            ExecStrategy::LegacyRows => {
                let ext_rows: Vec<Vec<Tuple>> = ext.iter().map(|b| b.to_tuples()).collect();
                let ext_refs: Vec<&[Tuple]> = ext_rows.iter().map(|v| v.as_slice()).collect();
                let ov_rows: HashMap<NodeId, Vec<Tuple>> = overrides
                    .iter()
                    .map(|(&id, b)| (id, b.to_tuples()))
                    .collect();
                self.run_legacy(doc, tokens, &ext_refs, &ov_rows, None)
            }
        }
    }

    // -- the columnar pipeline (production hot path) --

    fn run_columnar(
        &self,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        overrides: &HashMap<NodeId, TupleBatch>,
        mut agg: Option<&mut CorpusAgg>,
    ) -> DocResult {
        let mut slots: Vec<Option<TupleBatch>> = Vec::with_capacity(self.graph.nodes.len());
        slots.resize_with(self.graph.nodes.len(), || None);
        for node in &self.graph.nodes {
            if !self.live[node.id] {
                continue;
            }
            if let Some(b) = overrides.get(&node.id) {
                slots[node.id] = Some(b.clone());
                continue;
            }
            let t0 = self.profiler.start();
            // with a collector attached, GroupAgg additionally exports its
            // per-document partial; the emitted batch is identical to the
            // plain evaluation path (both run aggregate::group_agg_doc)
            let out = if let (OpKind::GroupAgg { cols }, Some(collector)) =
                (&node.kind, agg.as_deref_mut())
            {
                let input = slots[node.inputs[0]]
                    .as_ref()
                    .expect("topological order guarantees inputs are evaluated");
                let (batch, partial) = aggregate::group_agg_doc(cols, &node.schema, input);
                collector.partials.insert(node.id, partial);
                batch
            } else {
                self.eval_node_batch(node.id, doc, tokens, ext, &slots)
            };
            self.profiler.stop(node.id, t0);
            slots[node.id] = Some(out);
        }
        // move each output batch out of its slot (zero-copy); clone only
        // when a later output references the same node again
        let outputs = &self.graph.outputs;
        let batches: Vec<TupleBatch> = outputs
            .iter()
            .enumerate()
            .map(|(k, (_, id))| {
                let referenced_later = outputs[k + 1..].iter().any(|(_, j)| j == id);
                if referenced_later {
                    slots[*id].clone()
                } else {
                    slots[*id].take()
                }
                .unwrap_or_else(TupleBatch::empty)
            })
            .collect();
        DocResult::from_batches(doc.id, self.catalog.clone(), batches)
    }

    fn eval_node_batch(
        &self,
        id: NodeId,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        slots: &[Option<TupleBatch>],
    ) -> TupleBatch {
        let node = &self.graph.nodes[id];
        let input = |k: usize| -> &TupleBatch {
            slots[node.inputs[k]]
                .as_ref()
                .expect("topological order guarantees inputs are evaluated")
        };
        let ctx = EvalCtx {
            text: &doc.text,
            tokens,
        };
        match &node.kind {
            OpKind::DocScan => operators::doc_scan_batch(doc),
            OpKind::RegexExtract { regex, .. } => operators::regex_extract_batch(regex, doc),
            OpKind::DictExtract { matcher, .. } => operators::dict_extract_batch(matcher, doc),
            OpKind::Select { pred } => operators::select_batch(input(0), pred, &ctx),
            OpKind::Project { cols } => {
                operators::project_batch(input(0), cols, &ctx, &node.schema)
            }
            OpKind::Join { pred } => operators::join_batch(input(0), input(1), pred, &ctx),
            OpKind::Union => {
                let mut out = TupleBatch::like(input(0));
                for k in 0..node.inputs.len() {
                    out.extend_from(input(k));
                }
                out
            }
            OpKind::Consolidate { col, policy } => {
                operators::consolidate_batch(input(0), *col, *policy)
            }
            OpKind::Difference => operators::difference_batch(input(0), input(1)),
            OpKind::Block {
                col,
                max_gap,
                min_size,
            } => operators::block_batch(input(0), *col, *max_gap, *min_size),
            OpKind::Sort { keys } => operators::sort_batch(input(0), keys),
            OpKind::Limit { n } => operators::limit_batch(input(0), *n),
            // corpus of one: absorb this document's rows and finish
            // immediately (the collecting path in run_columnar also keeps
            // the partial for the session's cross-document merge)
            OpKind::GroupAgg { cols } => {
                aggregate::group_agg_doc(cols, &node.schema, input(0)).0
            }
            OpKind::TopK { k, score } => {
                aggregate::top_k(input(0), *k, score, &node.schema, &ctx)
            }
            OpKind::SubgraphExec {
                subgraph_id,
                output_idx,
                ..
            } => match &self.subgraph_runner {
                Some(r) => {
                    // inputs 1.. are the software-computed tuple streams
                    let streams: Vec<&TupleBatch> =
                        (1..node.inputs.len()).map(|k| input(k)).collect();
                    r.run_batch(
                        *subgraph_id,
                        *output_idx,
                        doc,
                        tokens,
                        &streams,
                        &node.schema,
                    )
                }
                None => panic!(
                    "graph contains SubgraphExec #{subgraph_id} but no runner is attached"
                ),
            },
            OpKind::ExtInput { slot, .. } => ext
                .get(*slot)
                .map(|b| (*b).clone())
                .unwrap_or_else(|| panic!("ExtInput slot {slot} not provided")),
        }
    }

    // -- the legacy row pipeline (reference baseline) --

    fn run_legacy(
        &self,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
        overrides: &HashMap<NodeId, Vec<Tuple>>,
        mut agg: Option<&mut CorpusAgg>,
    ) -> DocResult {
        let mut slots: Vec<Option<Vec<Tuple>>> = vec![None; self.graph.nodes.len()];
        for node in &self.graph.nodes {
            if !self.live[node.id] {
                continue;
            }
            if let Some(t) = overrides.get(&node.id) {
                slots[node.id] = Some(t.clone());
                continue;
            }
            let t0 = self.profiler.start();
            let out = if let (OpKind::GroupAgg { cols }, Some(collector)) =
                (&node.kind, agg.as_deref_mut())
            {
                let in_rows = slots[node.inputs[0]]
                    .as_deref()
                    .expect("topological order guarantees inputs are evaluated");
                let in_schema = &self.graph.nodes[node.inputs[0]].schema;
                let batch = TupleBatch::from_rows(in_schema, in_rows);
                let (b, partial) = aggregate::group_agg_doc(cols, &node.schema, &batch);
                collector.partials.insert(node.id, partial);
                b.to_tuples()
            } else {
                self.eval_node_rows(node.id, doc, tokens, ext, &slots)
            };
            self.profiler.stop(node.id, t0);
            slots[node.id] = Some(out);
        }
        let views = self
            .graph
            .outputs
            .iter()
            .map(|(_, id)| slots[*id].clone().unwrap_or_default())
            .collect();
        DocResult::from_rows(doc.id, self.catalog.clone(), views)
    }

    fn eval_node_rows(
        &self,
        id: NodeId,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
        slots: &[Option<Vec<Tuple>>],
    ) -> Vec<Tuple> {
        let node = &self.graph.nodes[id];
        let input = |k: usize| -> &[Tuple] {
            slots[node.inputs[k]]
                .as_deref()
                .expect("topological order guarantees inputs are evaluated")
        };
        let ctx = EvalCtx {
            text: &doc.text,
            tokens,
        };
        match &node.kind {
            OpKind::DocScan => operators::doc_scan(doc),
            OpKind::RegexExtract { regex, .. } => operators::regex_extract(regex, doc),
            OpKind::DictExtract { matcher, .. } => operators::dict_extract(matcher, doc),
            OpKind::Select { pred } => operators::select(input(0), pred, &ctx),
            OpKind::Project { cols } => operators::project(input(0), cols, &ctx),
            OpKind::Join { pred } => {
                let left_arity = self.graph.nodes[node.inputs[0]].schema.arity();
                operators::join(input(0), input(1), pred, left_arity, &ctx)
            }
            OpKind::Union => {
                let mut out = Vec::new();
                for k in 0..node.inputs.len() {
                    out.extend_from_slice(input(k));
                }
                out
            }
            OpKind::Consolidate { col, policy } => {
                operators::consolidate(input(0), *col, *policy)
            }
            OpKind::Difference => operators::difference(input(0), input(1)),
            OpKind::Block {
                col,
                max_gap,
                min_size,
            } => operators::block(input(0), *col, *max_gap, *min_size),
            OpKind::Sort { keys } => operators::sort(input(0), keys),
            OpKind::Limit { n } => input(0).iter().take(*n).cloned().collect(),
            // both strategies run the same aggregate implementation, so
            // their corpus-of-one outputs are byte-identical
            OpKind::GroupAgg { cols } => {
                let in_schema = &self.graph.nodes[node.inputs[0]].schema;
                let batch = TupleBatch::from_rows(in_schema, input(0));
                aggregate::group_agg_doc(cols, &node.schema, &batch).0.to_tuples()
            }
            OpKind::TopK { k, score } => {
                let in_schema = &self.graph.nodes[node.inputs[0]].schema;
                let batch = TupleBatch::from_rows(in_schema, input(0));
                aggregate::top_k(&batch, *k, score, &node.schema, &ctx).to_tuples()
            }
            OpKind::SubgraphExec {
                subgraph_id,
                output_idx,
                ..
            } => match &self.subgraph_runner {
                Some(r) => {
                    // inputs 1.. are the software-computed tuple streams
                    let streams: Vec<&[Tuple]> =
                        (1..node.inputs.len()).map(|k| input(k)).collect();
                    r.run(*subgraph_id, *output_idx, doc, tokens, &streams)
                }
                None => panic!(
                    "graph contains SubgraphExec #{subgraph_id} but no runner is attached"
                ),
            },
            OpKind::ExtInput { slot, .. } => ext
                .get(*slot)
                .map(|s| s.to_vec())
                .unwrap_or_else(|| panic!("ExtInput slot {slot} not provided")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(aql: &str) -> Executor {
        let g = crate::aql::compile(aql).unwrap();
        let prof = Arc::new(Profiler::for_graph(&g));
        Executor::new(Arc::new(g), prof)
    }

    fn doc(text: &str) -> Document {
        Document::new(0, text)
    }

    const PERSON_ORG: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');
        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 4)
          consolidate on ctx using 'ContainedWithin';
        output view PersonOrg;
    "#;

    #[test]
    fn end_to_end_person_org() {
        let ex = engine(PERSON_ORG);
        let d = doc("Laura Chiticariu works at IBM Research in Almaden.");
        let out = ex.run_doc(&d);
        let rows = &out["PersonOrg"];
        assert_eq!(rows.len(), 1, "{rows:?}");
        let person = rows[0][0].as_span().text(&d.text);
        let org = rows[0][1].as_span().text(&d.text);
        assert_eq!(person, "Laura Chiticariu");
        assert_eq!(org, "IBM Research");
    }

    #[test]
    fn no_match_empty_output() {
        let ex = engine(PERSON_ORG);
        let out = ex.run_doc(&doc("nothing to see here"));
        assert!(out["PersonOrg"].is_empty());
        assert_eq!(out.total_tuples(), 0);
    }

    #[test]
    fn consolidate_dedups_overlaps() {
        // "IBM Research" contains "IBM": the dictionary fires on both, so
        // the join yields two overlapping ctx spans for the same person and
        // ContainedWithin keeps only the larger one.
        let ex = engine(PERSON_ORG);
        let d = doc("Fred Reiss and Huaiyu Zhu are at IBM Research today.");
        let out = ex.run_doc(&d);
        let rows = &out["PersonOrg"];
        // "Fred Reiss" is 5 tokens away from IBM — outside FollowsTok(0,4);
        // "Huaiyu Zhu" is 2 away; its ctx with "IBM" is inside its ctx with
        // "IBM Research".
        assert_eq!(rows.len(), 1, "{rows:?}");
        assert_eq!(rows[0][0].as_span().text(&d.text), "Huaiyu Zhu");
        assert_eq!(rows[0][1].as_span().text(&d.text), "IBM Research");
    }

    #[test]
    fn union_view_executes() {
        let ex = engine(
            "create view V as \
             (extract regex /cat/ on d.text as m from Document d) \
             union all \
             (extract regex /dog/ on d.text as m from Document d); \
             output view V;",
        );
        let out = ex.run_doc(&doc("cat dog cat"));
        assert_eq!(out["V"].len(), 3);
    }

    #[test]
    fn sort_and_limit() {
        let ex = engine(
            "create view A as extract regex /[a-z]+/ on d.text as m from Document d; \
             create view V as select a.m as m from A a order by m limit 2; \
             output view V;",
        );
        let d = doc("zz yy xx ww");
        let out = ex.run_doc(&d);
        let rows = &out["V"];
        assert_eq!(rows.len(), 2);
        // sorted by span (begin asc): zz then yy
        assert_eq!(rows[0][0].as_span().text(&d.text), "zz");
    }

    #[test]
    fn profiler_accumulates_by_operator() {
        let ex = engine(PERSON_ORG);
        let d = doc("Laura Chiticariu works at IBM Research in Almaden.");
        for _ in 0..10 {
            ex.run_doc(&d);
        }
        let profile = ex.profiler().snapshot(ex.graph());
        let total = profile.total_ns();
        assert!(total > 0);
        let frac = profile.fraction_extraction();
        assert!(frac > 0.0 && frac <= 1.0, "extraction fraction {frac}");
        // every named bucket fraction sums to ~1
        let sum: f64 = profile.by_operator().values().map(|v| v.fraction).sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
    }

    #[test]
    fn multiple_output_views() {
        let ex = engine(
            "create view A as extract regex /a+/ on d.text as m from Document d; \
             create view B as extract regex /b+/ on d.text as m from Document d; \
             output view A; output view B;",
        );
        let out = ex.run_doc(&doc("aa bb"));
        assert_eq!(out.num_views(), 2);
        assert_eq!(out["A"].len(), 1);
        assert_eq!(out["B"].len(), 1);
    }

    #[test]
    #[should_panic(expected = "no runner is attached")]
    fn subgraph_without_runner_panics() {
        use crate::aog::{FieldType, Graph, OpKind, Schema};
        let mut g = Graph::new();
        let doc_n = g.add(OpKind::DocScan, vec![]).unwrap();
        let sg = g
            .add(
                OpKind::SubgraphExec {
                    subgraph_id: 0,
                    output_idx: 0,
                    schema: Schema::of(&[("m", FieldType::Span)]),
                },
                vec![doc_n],
            )
            .unwrap();
        g.add_output("V", sg).unwrap();
        let ex = Executor::new(Arc::new(g), Arc::new(Profiler::disabled()));
        ex.run_doc(&doc("x"));
    }

    #[test]
    fn ext_input_injection() {
        use crate::aog::{FieldType, Graph, OpKind, Schema, Value};
        use crate::text::Span;
        let mut g = Graph::new();
        let e = g
            .add(
                OpKind::ExtInput {
                    slot: 0,
                    schema: Schema::of(&[("m", FieldType::Span)]),
                },
                vec![],
            )
            .unwrap();
        g.add_output("V", e).unwrap();
        let ex = Executor::new(Arc::new(g), Arc::new(Profiler::disabled()));
        let d = doc("hello");
        let tokens = d.token_index();
        let injected: Vec<Tuple> = vec![vec![Value::Span(Span::new(0, 5))]];
        let out = ex.run_doc_with(&d, &tokens, &[&injected], &HashMap::new());
        assert_eq!(out["V"], injected);
    }

    #[test]
    fn override_replaces_node_output() {
        use crate::aog::Value;
        use crate::text::Span;
        let ex = engine(
            "create view A as extract regex /zzz/ on d.text as m from Document d; \
             output view A;",
        );
        let d = doc("no matches here");
        let tokens = d.token_index();
        // node 1 is the regex node; override it with a fake match
        let mut overrides = HashMap::new();
        let fake: Vec<Tuple> = vec![vec![Value::Span(Span::new(0, 2))]];
        overrides.insert(1usize, fake.clone());
        let out = ex.run_doc_with(&d, &tokens, &[], &overrides);
        assert_eq!(out["A"], fake);
    }

    #[test]
    fn view_handles_resolve_with_schema() {
        let ex = engine(PERSON_ORG);
        let h = ex.catalog().resolve("PersonOrg").expect("view exists");
        assert_eq!(h.name(), "PersonOrg");
        assert_eq!(h.schema().arity(), 3);
        assert_eq!(h.schema().index_of("person"), Some(0));
        assert_eq!(h.schema().index_of("org"), Some(1));
        assert!(ex.catalog().resolve("Nope").is_none());

        let d = doc("Laura Chiticariu works at IBM Research in Almaden.");
        let out = ex.run_doc(&d);
        // handle-indexed and name-indexed access agree
        assert_eq!(out[h], out["PersonOrg"]);
        assert_eq!(out.view(h).len(), 1);
        assert_eq!(out.view_batch(h).len(), 1);
        assert_eq!(out.doc_id(), d.id);
    }

    #[test]
    #[should_panic(expected = "no output view named 'Wrong'")]
    fn unknown_view_name_panics_with_available_views() {
        let ex = engine(PERSON_ORG);
        let out = ex.run_doc(&doc("x"));
        let _ = &out["Wrong"];
    }

    #[test]
    #[should_panic(expected = "does not belong to this engine")]
    fn foreign_view_handle_panics() {
        let a = engine(PERSON_ORG);
        let b = engine(
            "create view Other as extract regex /x/ on d.text as m from Document d; \
             output view Other;",
        );
        let h = b.catalog().resolve("Other").unwrap().clone();
        let out = a.run_doc(&doc("x"));
        let _ = out.view(&h);
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_doc_output_shim() {
        let ex = engine(PERSON_ORG);
        let d = doc("Laura Chiticariu works at IBM Research in Almaden.");
        let typed = ex.run_doc(&d);
        let total = typed.total_tuples();
        let legacy = typed.into_output();
        assert_eq!(legacy.total_tuples(), total);
        assert_eq!(legacy.views["PersonOrg"].len(), 1);
    }

    #[test]
    fn dead_views_not_computed() {
        // A view that is never output should not contribute profile time.
        let ex = engine(
            "create view Dead as extract regex /x+/ on d.text as m from Document d; \
             create view Live as extract regex /y+/ on d.text as m from Document d; \
             output view Live;",
        );
        let out = ex.run_doc(&doc("xxx yyy"));
        assert_eq!(out.num_views(), 1);
        let profile = ex.profiler().snapshot(ex.graph());
        // the dead regex node must have zero recorded time
        let per_node = profile.per_node();
        let dead_id = ex
            .graph()
            .nodes
            .iter()
            .find(|n| n.view.as_deref() == Some("Dead"))
            .unwrap()
            .id;
        assert_eq!(per_node[dead_id], 0);
    }

    #[test]
    fn legacy_strategy_matches_columnar_byte_for_byte() {
        let col = engine(PERSON_ORG);
        let leg = {
            let g = crate::aql::compile(PERSON_ORG).unwrap();
            Executor::new(Arc::new(g), Arc::new(Profiler::disabled()))
                .with_strategy(ExecStrategy::LegacyRows)
        };
        assert_eq!(col.strategy(), ExecStrategy::Columnar);
        assert_eq!(leg.strategy(), ExecStrategy::LegacyRows);
        for text in [
            "Laura Chiticariu works at IBM Research in Almaden.",
            "Fred Reiss and Huaiyu Zhu are at IBM Research today.",
            "nothing to see here",
            "",
        ] {
            let d = doc(text);
            assert_eq!(
                col.run_doc(&d).views(),
                leg.run_doc(&d).views(),
                "strategies diverged on {text:?}"
            );
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [ExecStrategy::Columnar, ExecStrategy::LegacyRows] {
            assert_eq!(ExecStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ExecStrategy::parse("bogus"), None);
        assert_eq!(ExecStrategy::default(), ExecStrategy::Columnar);
    }

    const TOP_TERMS: &str = "create view E as \
         extract regex /[A-Z][a-z]+/ on d.text as m from Document d; \
         create view Top as \
         select GetText(e.m) as term, Count() as n, CountDocs() as docs \
         from E e group by term score n top 2; \
         output view Top;";

    #[test]
    fn group_agg_runs_as_corpus_of_one_per_doc() {
        use crate::aog::Value;
        let ex = engine(TOP_TERMS);
        let d = doc("Alice met Bob and Alice met Carol and Alice waved");
        let out = ex.run_doc(&d);
        let rows = &out["Top"];
        // top 2 by count: Alice (3), then the Bob/Carol tie resolves by
        // term bytes -> Bob
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert_eq!(rows[0][0], Value::Str("Alice".into()));
        assert_eq!(rows[0][1], Value::Int(3));
        assert_eq!(rows[0][2], Value::Int(1));
        assert_eq!(rows[0][3], Value::Int(3)); // score = n
        assert_eq!(rows[1][0], Value::Str("Bob".into()));
    }

    #[test]
    fn run_doc_agg_exports_partials_that_merge_across_docs() {
        use crate::aog::Value;
        let ex = engine(TOP_TERMS);
        let (r1, mut agg) = ex.run_doc_agg(&doc("Alice met Bob"));
        let (r2, a2) = ex.run_doc_agg(&doc("Alice met Carol and Alice"));
        // per-doc results equal the plain run_doc output
        assert_eq!(r1.views(), ex.run_doc(&doc("Alice met Bob")).views());
        assert_eq!(r2.total_tuples() > 0, true);
        assert!(!agg.is_empty());
        agg.merge(&a2);
        let corpus = ex.corpus_results(&agg);
        assert_eq!(corpus.len(), 1);
        assert_eq!(corpus[0].view, "Top");
        let rows = &corpus[0].rows;
        // Alice: 3 mentions across 2 docs
        assert_eq!(rows[0][0], Value::Str("Alice".into()));
        assert_eq!(rows[0][1], Value::Int(3));
        assert_eq!(rows[0][2], Value::Int(2));
    }

    #[test]
    fn corpus_results_empty_state_is_schema_correct() {
        let ex = engine(TOP_TERMS);
        let corpus = ex.corpus_results(&CorpusAgg::default());
        assert_eq!(corpus.len(), 1);
        assert!(corpus[0].rows.is_empty());
        assert_eq!(corpus[0].schema.arity(), 4);
    }

    #[test]
    fn agg_strategies_agree() {
        let col = engine(TOP_TERMS);
        let leg = {
            let g = crate::aql::compile(TOP_TERMS).unwrap();
            Executor::new(Arc::new(g), Arc::new(Profiler::disabled()))
                .with_strategy(ExecStrategy::LegacyRows)
        };
        for text in ["Alice met Bob and Alice", "nothing lower case", ""] {
            let d = doc(text);
            assert_eq!(
                col.run_doc(&d).views(),
                leg.run_doc(&d).views(),
                "strategies diverged on {text:?}"
            );
        }
    }

    #[test]
    fn doc_result_counts_without_materializing() {
        let ex = engine(PERSON_ORG);
        let d = doc("Laura Chiticariu works at IBM Research in Almaden.");
        let out = ex.run_doc(&d);
        // batch accessors and counters work pre-materialization
        assert_eq!(out.total_tuples(), 1);
        assert_eq!(out.batches().len(), 1);
        assert_eq!(out.batches()[0].len(), 1);
        // then the lazy row view agrees
        assert_eq!(out.views()[0].len(), 1);
    }
}
