//! Software implementations of the individual operators.
//!
//! These are the baselines the paper profiles (Fig 4): extraction operators
//! scan the whole document and dominate; relational operators work on the
//! (much smaller) extracted tuple sets.

use std::cmp::Ordering;

use crate::aog::{EvalCtx, Expr, Tuple, Value};
use crate::dict::AhoCorasick;
use crate::regex::CompiledRegex;
use crate::text::span::{consolidate as consolidate_spans, ConsolidatePolicy};
use crate::text::{Document, Span};

/// `DocScan`: one tuple covering the whole document.
pub fn doc_scan(doc: &Document) -> Vec<Tuple> {
    vec![vec![Value::Span(Span::new(0, doc.len() as u32))]]
}

/// `RegularExpression`: all matches (leftmost-longest, non-overlapping).
pub fn regex_extract(regex: &CompiledRegex, doc: &Document) -> Vec<Tuple> {
    regex
        .find_all(&doc.text)
        .into_iter()
        .map(|m| vec![Value::Span(m.span)])
        .collect()
}

/// `Dictionary`: token-boundary dictionary matches.
pub fn dict_extract(matcher: &AhoCorasick, doc: &Document) -> Vec<Tuple> {
    matcher
        .find_token_matches(doc.text.as_bytes())
        .into_iter()
        .map(|m| vec![Value::Span(m.span)])
        .collect()
}

/// `Select`: predicate filter.
pub fn select(input: &[Tuple], pred: &Expr, ctx: &EvalCtx<'_>) -> Vec<Tuple> {
    input
        .iter()
        .filter(|t| pred.eval(t, ctx).as_bool())
        .cloned()
        .collect()
}

/// `Project`: compute output columns.
pub fn project(input: &[Tuple], cols: &[(String, Expr)], ctx: &EvalCtx<'_>) -> Vec<Tuple> {
    input
        .iter()
        .map(|t| cols.iter().map(|(_, e)| e.eval(t, ctx)).collect())
        .collect()
}

/// `Join`: predicate join. A sort-based *band join* fast path handles the
/// dominant span-adjacency predicates (`Follows`/`FollowsTok`) — SystemT's
/// cost-based optimizer does exactly this, which is why its relational
/// operators are cheap relative to extraction (paper Fig 4). Everything
/// else falls back to a nested loop.
pub fn join(
    left: &[Tuple],
    right: &[Tuple],
    pred: &Expr,
    left_arity: usize,
    ctx: &EvalCtx<'_>,
) -> Vec<Tuple> {
    if let Some((lcol, rcol, band)) = band_window(pred, left_arity) {
        return band_join(left, right, pred, lcol, rcol, band, ctx);
    }
    let mut out = Vec::new();
    for l in left {
        for r in right {
            let mut combined = Vec::with_capacity(l.len() + r.len());
            combined.extend_from_slice(l);
            combined.extend_from_slice(r);
            if pred.eval(&combined, ctx).as_bool() {
                out.push(combined);
            }
        }
    }
    out
}

/// The candidate window for a band-joinable conjunct.
enum Band {
    /// `Follows(l, r, min, max)`: r.begin ∈ [l.end+min, l.end+max].
    Chars { min: u32, max: u32 },
    /// `FollowsTok(l, r, min, max)`: r.begin bounded via the token index.
    Toks { max: i64 },
}

/// Detect a `Follows`/`FollowsTok(Col l, Col r, min, max)` conjunct with
/// `l` from the left side and `r` from the right side of the join.
fn band_window(pred: &Expr, left_arity: usize) -> Option<(usize, usize, Band)> {
    // search conjuncts
    match pred {
        Expr::And(a, b) => band_window(a, left_arity).or_else(|| band_window(b, left_arity)),
        Expr::Call(f @ (crate::aog::expr::Func::Follows | crate::aog::expr::Func::FollowsTok), args) => {
            if let [Expr::Col(l), Expr::Col(r), Expr::LitInt(min), Expr::LitInt(max)] =
                args.as_slice()
            {
                if *l < left_arity && *r >= left_arity {
                    let band = match f {
                        crate::aog::expr::Func::Follows => Band::Chars {
                            min: (*min).max(0) as u32,
                            max: (*max).max(0) as u32,
                        },
                        _ => Band::Toks { max: (*max).max(0) },
                    };
                    return Some((*l, *r - left_arity, band));
                }
            }
            None
        }
        _ => None,
    }
}

fn band_join(
    left: &[Tuple],
    right: &[Tuple],
    pred: &Expr,
    lcol: usize,
    rcol: usize,
    band: Band,
    ctx: &EvalCtx<'_>,
) -> Vec<Tuple> {
    // sort right tuple indices by span begin at rcol
    let mut order: Vec<usize> = (0..right.len()).collect();
    order.sort_by_key(|&i| right[i][rcol].as_span().begin);
    let begins: Vec<u32> = order.iter().map(|&i| right[i][rcol].as_span().begin).collect();

    let mut out = Vec::new();
    for l in left {
        let a = l[lcol].as_span();
        let (lo, hi) = match band {
            Band::Chars { min, max } => {
                (a.end.saturating_add(min), a.end.saturating_add(max))
            }
            Band::Toks { max } => {
                // exact over-approximation: r.begin must lie at or before
                // the end of the (max+1)-th token after a.end
                let idx = ctx.tokens.first_token_at_or_after(a.end);
                let upper = idx + max as usize + 1;
                let bound = ctx
                    .tokens
                    .tokens()
                    .get(upper)
                    .map(|t| t.span.end)
                    .unwrap_or(u32::MAX);
                (a.end, bound)
            }
        };
        let start = begins.partition_point(|&b| b < lo);
        // candidates in original right-input order, so the output order is
        // identical to the nested loop's (downstream Consolidate's
        // first-tuple-wins rule must not depend on the join algorithm)
        let mut cands: Vec<usize> = (start..begins.len())
            .take_while(|&k| begins[k] <= hi)
            .map(|k| order[k])
            .collect();
        cands.sort_unstable();
        for ri in cands {
            let r = &right[ri];
            let mut combined = Vec::with_capacity(l.len() + r.len());
            combined.extend_from_slice(l);
            combined.extend_from_slice(r);
            if pred.eval(&combined, ctx).as_bool() {
                out.push(combined);
            }
        }
    }
    out
}

/// `Consolidate`: keep tuples whose span (at `col`) survives consolidation;
/// one tuple per surviving span (first occurrence wins, as in SystemT).
pub fn consolidate(input: &[Tuple], col: usize, policy: ConsolidatePolicy) -> Vec<Tuple> {
    if input.is_empty() {
        return Vec::new();
    }
    let spans: Vec<Span> = input.iter().map(|t| t[col].as_span()).collect();
    let kept = consolidate_spans(&spans, policy);
    let mut out = Vec::with_capacity(kept.len());
    for k in kept {
        if let Some(t) = input.iter().find(|t| t[col].as_span() == k) {
            out.push(t.clone());
        }
    }
    out
}

/// `Difference` (SystemT `minus`): tuples of `left` not present in
/// `right` (set semantics on whole tuples; duplicates in `left` collapse).
pub fn difference(left: &[Tuple], right: &[Tuple]) -> Vec<Tuple> {
    let mut out: Vec<Tuple> = Vec::new();
    for l in left {
        if right.iter().any(|r| r == l) {
            continue;
        }
        if out.iter().any(|o| o == l) {
            continue;
        }
        out.push(l.clone());
    }
    out
}

/// `Block`: group spans within `max_gap` bytes of the previous span's end
/// into blocks; emit the covering span of every block with at least
/// `min_size` members. Input is sorted by the block column first
/// (the operator is self-sorting, like SystemT's).
pub fn block(input: &[Tuple], col: usize, max_gap: u32, min_size: usize) -> Vec<Tuple> {
    let mut spans: Vec<Span> = input.iter().map(|t| t[col].as_span()).collect();
    spans.sort();
    let mut out = Vec::new();
    let mut i = 0;
    while i < spans.len() {
        let mut members = 1;
        let mut cover = spans[i];
        let mut j = i + 1;
        while j < spans.len() {
            let s = spans[j];
            if s.begin >= cover.end && s.begin - cover.end > max_gap {
                break;
            }
            cover = cover.combine(&s);
            members += 1;
            j += 1;
        }
        if members >= min_size {
            out.push(vec![Value::Span(cover)]);
        }
        i = j;
    }
    out
}

/// Total order over values of the same type (used by Sort; null sorts last).
pub fn cmp_values(a: &Value, b: &Value) -> Ordering {
    match (a, b) {
        (Value::Span(x), Value::Span(y)) => x.cmp(y),
        (Value::Int(x), Value::Int(y)) => x.cmp(y),
        (Value::Float(x), Value::Float(y)) => x.partial_cmp(y).unwrap_or(Ordering::Equal),
        (Value::Bool(x), Value::Bool(y)) => x.cmp(y),
        (Value::Str(x), Value::Str(y)) => x.cmp(y),
        (Value::Null, Value::Null) => Ordering::Equal,
        (Value::Null, _) => Ordering::Greater,
        (_, Value::Null) => Ordering::Less,
        _ => Ordering::Equal, // mixed types cannot occur in a typed column
    }
}

/// Lexicographic tuple comparison over `keys`.
pub fn cmp_tuples(a: &Tuple, b: &Tuple, keys: &[usize]) -> Ordering {
    for &k in keys {
        let o = cmp_values(&a[k], &b[k]);
        if o != Ordering::Equal {
            return o;
        }
    }
    Ordering::Equal
}

/// `Sort`: stable sort by key columns.
pub fn sort(input: &[Tuple], keys: &[usize]) -> Vec<Tuple> {
    let mut out = input.to_vec();
    out.sort_by(|a, b| cmp_tuples(a, b, keys));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::expr::{CmpOp, Func};
    use crate::text::Tokenizer;

    fn ctx(text: &'static str) -> EvalCtx<'static> {
        let tokens = Box::leak(Box::new(Tokenizer::standard().tokenize(text)));
        EvalCtx { text, tokens }
    }

    fn span_t(b: u32, e: u32) -> Tuple {
        vec![Value::Span(Span::new(b, e))]
    }

    #[test]
    fn doc_scan_covers_text() {
        let d = Document::new(0, "hello");
        assert_eq!(doc_scan(&d), vec![vec![Value::Span(Span::new(0, 5))]]);
    }

    #[test]
    fn select_filters() {
        let c = ctx("aaa bb c");
        let input = vec![span_t(0, 3), span_t(4, 6), span_t(7, 8)];
        let pred = Expr::Cmp(
            Box::new(Expr::Call(Func::GetLength, vec![Expr::Col(0)])),
            CmpOp::Ge,
            Box::new(Expr::LitInt(2)),
        );
        let out = select(&input, &pred, &c);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn project_computes() {
        let c = ctx("hello world");
        let input = vec![span_t(0, 5)];
        let cols = vec![
            (
                "len".to_string(),
                Expr::Call(Func::GetLength, vec![Expr::Col(0)]),
            ),
            (
                "txt".to_string(),
                Expr::Call(Func::GetText, vec![Expr::Col(0)]),
            ),
        ];
        let out = project(&input, &cols, &c);
        assert_eq!(out[0][0], Value::Int(5));
        assert_eq!(out[0][1], Value::Str("hello".into()));
    }

    #[test]
    fn join_cross_and_pred() {
        let c = ctx("aa bb cc dd");
        let left = vec![span_t(0, 2), span_t(6, 8)];
        let right = vec![span_t(3, 5), span_t(9, 11)];
        let pred = Expr::Call(
            Func::Follows,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(0), Expr::LitInt(1)],
        );
        let out = join(&left, &right, &pred, 1, &c);
        // (0,2)->(3,5) gap1 ok; (0,2)->(9,11) gap7 no; (6,8)->(9,11) gap1 ok;
        // (6,8)->(3,5) not follows
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
    }

    #[test]
    fn consolidate_keeps_first_tuple_per_span() {
        let input = vec![
            vec![Value::Span(Span::new(0, 10)), Value::Int(1)],
            vec![Value::Span(Span::new(2, 5)), Value::Int(2)],
            vec![Value::Span(Span::new(0, 10)), Value::Int(3)],
        ];
        let out = consolidate(&input, 0, ConsolidatePolicy::ContainedWithin);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0][1], Value::Int(1)); // first wins
    }

    #[test]
    fn sort_by_int_then_span() {
        let input = vec![
            vec![Value::Int(2), Value::Span(Span::new(5, 6))],
            vec![Value::Int(1), Value::Span(Span::new(9, 10))],
            vec![Value::Int(2), Value::Span(Span::new(1, 2))],
        ];
        let out = sort(&input, &[0, 1]);
        assert_eq!(out[0][0], Value::Int(1));
        assert_eq!(out[1][1], Value::Span(Span::new(1, 2)));
    }

    #[test]
    fn cmp_values_null_last() {
        assert_eq!(cmp_values(&Value::Null, &Value::Int(1)), Ordering::Greater);
        assert_eq!(cmp_values(&Value::Int(1), &Value::Null), Ordering::Less);
        assert_eq!(cmp_values(&Value::Null, &Value::Null), Ordering::Equal);
    }
}
