//! Software implementations of the individual operators.
//!
//! These are the baselines the paper profiles (Fig 4): extraction operators
//! scan the whole document and dominate; relational operators work on the
//! (much smaller) extracted tuple sets.
//!
//! Each operator exists in two forms:
//! * the **columnar** `*_batch` form over [`TupleBatch`] — the production
//!   hot path (no per-tuple heap allocation, arena-recycled buffers);
//! * the row-at-a-time `Vec<Tuple>` form — the seed's semantics, kept as
//!   the reference baseline behind
//!   [`ExecStrategy::LegacyRows`](super::ExecStrategy) for the
//!   columnar-vs-legacy differential suite and the old-vs-new benchmark.
//!
//! The two forms must stay **byte-identical** in output content and order
//! (`rust/tests/columnar.rs` enforces this across T1–T5 × every
//! `PartitionMode`); in particular the band join emits candidates in
//! original right-input order in both.

use std::cmp::Ordering;
use std::collections::HashMap;

use crate::aog::{EvalCtx, Expr, Schema, Tuple, Value};
use crate::dict::AhoCorasick;
use crate::exec::batch::{JoinRow, TupleBatch};
use crate::regex::CompiledRegex;
use crate::text::span::{consolidate as consolidate_spans, ConsolidatePolicy};
use crate::text::{Document, Span};

/// `DocScan`: one tuple covering the whole document.
pub fn doc_scan(doc: &Document) -> Vec<Tuple> {
    vec![vec![Value::Span(Span::new(0, doc.len() as u32))]]
}

/// `RegularExpression`: all matches (leftmost-longest, non-overlapping).
pub fn regex_extract(regex: &CompiledRegex, doc: &Document) -> Vec<Tuple> {
    regex
        .find_all(&doc.text)
        .into_iter()
        .map(|m| vec![Value::Span(m.span)])
        .collect()
}

/// `Dictionary`: token-boundary dictionary matches.
pub fn dict_extract(matcher: &AhoCorasick, doc: &Document) -> Vec<Tuple> {
    matcher
        .find_token_matches(doc.text.as_bytes())
        .into_iter()
        .map(|m| vec![Value::Span(m.span)])
        .collect()
}

/// `Select`: predicate filter.
pub fn select(input: &[Tuple], pred: &Expr, ctx: &EvalCtx<'_>) -> Vec<Tuple> {
    input
        .iter()
        .filter(|t| pred.eval(t, ctx).as_bool())
        .cloned()
        .collect()
}

/// `Project`: compute output columns.
pub fn project(input: &[Tuple], cols: &[(String, Expr)], ctx: &EvalCtx<'_>) -> Vec<Tuple> {
    input
        .iter()
        .map(|t| cols.iter().map(|(_, e)| e.eval(t, ctx)).collect())
        .collect()
}

/// `Join`: predicate join. A sort-based *band join* fast path handles the
/// dominant span-adjacency predicates (`Follows`/`FollowsTok`) — SystemT's
/// cost-based optimizer does exactly this, which is why its relational
/// operators are cheap relative to extraction (paper Fig 4). Everything
/// else falls back to a nested loop.
pub fn join(
    left: &[Tuple],
    right: &[Tuple],
    pred: &Expr,
    left_arity: usize,
    ctx: &EvalCtx<'_>,
) -> Vec<Tuple> {
    if let Some((lcol, rcol, band)) = band_window(pred, left_arity) {
        return band_join(left, right, pred, lcol, rcol, band, ctx);
    }
    let mut out = Vec::new();
    for l in left {
        for r in right {
            let mut combined = Vec::with_capacity(l.len() + r.len());
            combined.extend_from_slice(l);
            combined.extend_from_slice(r);
            if pred.eval(&combined, ctx).as_bool() {
                out.push(combined);
            }
        }
    }
    out
}

/// The candidate window for a band-joinable conjunct.
enum Band {
    /// `Follows(l, r, min, max)`: r.begin ∈ [l.end+min, l.end+max].
    Chars { min: u32, max: u32 },
    /// `FollowsTok(l, r, min, max)`: r.begin bounded via the token index.
    Toks { max: i64 },
}

/// Detect a `Follows`/`FollowsTok(Col l, Col r, min, max)` conjunct with
/// `l` from the left side and `r` from the right side of the join.
fn band_window(pred: &Expr, left_arity: usize) -> Option<(usize, usize, Band)> {
    // search conjuncts
    match pred {
        Expr::And(a, b) => band_window(a, left_arity).or_else(|| band_window(b, left_arity)),
        Expr::Call(f @ (crate::aog::expr::Func::Follows | crate::aog::expr::Func::FollowsTok), args) => {
            if let [Expr::Col(l), Expr::Col(r), Expr::LitInt(min), Expr::LitInt(max)] =
                args.as_slice()
            {
                if *l < left_arity && *r >= left_arity {
                    let band = match f {
                        crate::aog::expr::Func::Follows => Band::Chars {
                            min: (*min).max(0) as u32,
                            max: (*max).max(0) as u32,
                        },
                        _ => Band::Toks { max: (*max).max(0) },
                    };
                    return Some((*l, *r - left_arity, band));
                }
            }
            None
        }
        _ => None,
    }
}

fn band_join(
    left: &[Tuple],
    right: &[Tuple],
    pred: &Expr,
    lcol: usize,
    rcol: usize,
    band: Band,
    ctx: &EvalCtx<'_>,
) -> Vec<Tuple> {
    // sort right tuple indices by span begin at rcol
    let mut order: Vec<usize> = (0..right.len()).collect();
    order.sort_by_key(|&i| right[i][rcol].as_span().begin);
    let begins: Vec<u32> = order.iter().map(|&i| right[i][rcol].as_span().begin).collect();

    let mut out = Vec::new();
    for l in left {
        let a = l[lcol].as_span();
        let (lo, hi) = match band {
            Band::Chars { min, max } => {
                (a.end.saturating_add(min), a.end.saturating_add(max))
            }
            Band::Toks { max } => {
                // exact over-approximation: r.begin must lie at or before
                // the end of the (max+1)-th token after a.end
                let idx = ctx.tokens.first_token_at_or_after(a.end);
                let upper = idx + max as usize + 1;
                let bound = ctx
                    .tokens
                    .tokens()
                    .get(upper)
                    .map(|t| t.span.end)
                    .unwrap_or(u32::MAX);
                (a.end, bound)
            }
        };
        let start = begins.partition_point(|&b| b < lo);
        // candidates in original right-input order, so the output order is
        // identical to the nested loop's (downstream Consolidate's
        // first-tuple-wins rule must not depend on the join algorithm)
        let mut cands: Vec<usize> = (start..begins.len())
            .take_while(|&k| begins[k] <= hi)
            .map(|k| order[k])
            .collect();
        cands.sort_unstable();
        for ri in cands {
            let r = &right[ri];
            let mut combined = Vec::with_capacity(l.len() + r.len());
            combined.extend_from_slice(l);
            combined.extend_from_slice(r);
            if pred.eval(&combined, ctx).as_bool() {
                out.push(combined);
            }
        }
    }
    out
}

/// `Consolidate`: keep tuples whose span (at `col`) survives consolidation;
/// one tuple per surviving span (first occurrence wins, as in SystemT).
pub fn consolidate(input: &[Tuple], col: usize, policy: ConsolidatePolicy) -> Vec<Tuple> {
    if input.is_empty() {
        return Vec::new();
    }
    let spans: Vec<Span> = input.iter().map(|t| t[col].as_span()).collect();
    let kept = consolidate_spans(&spans, policy);
    let mut out = Vec::with_capacity(kept.len());
    for k in kept {
        if let Some(t) = input.iter().find(|t| t[col].as_span() == k) {
            out.push(t.clone());
        }
    }
    out
}

/// `Difference` (SystemT `minus`): tuples of `left` not present in
/// `right` (set semantics on whole tuples; duplicates in `left` collapse).
pub fn difference(left: &[Tuple], right: &[Tuple]) -> Vec<Tuple> {
    let mut out: Vec<Tuple> = Vec::new();
    for l in left {
        if right.iter().any(|r| r == l) {
            continue;
        }
        if out.iter().any(|o| o == l) {
            continue;
        }
        out.push(l.clone());
    }
    out
}

/// `Block`: group spans within `max_gap` bytes of the previous span's end
/// into blocks; emit the covering span of every block with at least
/// `min_size` members. Input is sorted by the block column first
/// (the operator is self-sorting, like SystemT's).
pub fn block(input: &[Tuple], col: usize, max_gap: u32, min_size: usize) -> Vec<Tuple> {
    let mut spans: Vec<Span> = input.iter().map(|t| t[col].as_span()).collect();
    spans.sort();
    let mut out = Vec::new();
    let mut i = 0;
    while i < spans.len() {
        let mut members = 1;
        let mut cover = spans[i];
        let mut j = i + 1;
        while j < spans.len() {
            let s = spans[j];
            if s.begin >= cover.end && s.begin - cover.end > max_gap {
                break;
            }
            cover = cover.combine(&s);
            members += 1;
            j += 1;
        }
        if members >= min_size {
            out.push(vec![Value::Span(cover)]);
        }
        i = j;
    }
    out
}

/// Total order over values of the same type (used by Sort; null sorts last).
pub fn cmp_values(a: &Value, b: &Value) -> Ordering {
    match (a, b) {
        (Value::Span(x), Value::Span(y)) => x.cmp(y),
        (Value::Int(x), Value::Int(y)) => x.cmp(y),
        (Value::Float(x), Value::Float(y)) => x.partial_cmp(y).unwrap_or(Ordering::Equal),
        (Value::Bool(x), Value::Bool(y)) => x.cmp(y),
        (Value::Str(x), Value::Str(y)) => x.cmp(y),
        (Value::Null, Value::Null) => Ordering::Equal,
        (Value::Null, _) => Ordering::Greater,
        (_, Value::Null) => Ordering::Less,
        _ => Ordering::Equal, // mixed types cannot occur in a typed column
    }
}

/// Lexicographic tuple comparison over `keys`.
pub fn cmp_tuples(a: &Tuple, b: &Tuple, keys: &[usize]) -> Ordering {
    for &k in keys {
        let o = cmp_values(&a[k], &b[k]);
        if o != Ordering::Equal {
            return o;
        }
    }
    Ordering::Equal
}

/// `Sort`: stable sort by key columns.
pub fn sort(input: &[Tuple], keys: &[usize]) -> Vec<Tuple> {
    let mut out = input.to_vec();
    out.sort_by(|a, b| cmp_tuples(a, b, keys));
    out
}

// ---------------------------------------------------------------------------
// Columnar (TupleBatch) operator forms — the production hot path.

/// `DocScan` (columnar): one row covering the whole document.
pub fn doc_scan_batch(doc: &Document) -> TupleBatch {
    let mut out = TupleBatch::single_span();
    out.push_span(Span::new(0, doc.len() as u32));
    out
}

/// `RegularExpression` (columnar): matches emitted straight into the
/// arena-backed span column — no per-match tuples.
pub fn regex_extract_batch(regex: &CompiledRegex, doc: &Document) -> TupleBatch {
    let mut out = TupleBatch::single_span();
    out.fill_spans(|spans| regex.find_all_spans_into(&doc.text, spans));
    out
}

/// `Dictionary` (columnar): token-boundary matches emitted straight into
/// the span column.
pub fn dict_extract_batch(matcher: &AhoCorasick, doc: &Document) -> TupleBatch {
    let mut out = TupleBatch::single_span();
    out.fill_spans(|spans| matcher.find_token_spans_into(doc.text.as_bytes(), spans));
    out
}

/// `Select` (columnar): predicate filter, copying surviving rows
/// column-wise.
pub fn select_batch(input: &TupleBatch, pred: &Expr, ctx: &EvalCtx<'_>) -> TupleBatch {
    let mut out = TupleBatch::like(input);
    for i in 0..input.len() {
        if pred.eval(&input.row(i), ctx).as_bool() {
            out.push_row_from(input, i);
        }
    }
    out
}

/// `Project` (columnar): compute output columns row by row (output column
/// types come from the node's compile-time schema).
pub fn project_batch(
    input: &TupleBatch,
    cols: &[(String, Expr)],
    ctx: &EvalCtx<'_>,
    out_schema: &Schema,
) -> TupleBatch {
    let mut out = TupleBatch::for_schema(out_schema);
    for i in 0..input.len() {
        let row = input.row(i);
        out.push_row(cols.iter().map(|(_, e)| e.eval(&row, ctx)));
    }
    out
}

/// `Join` (columnar): same plan selection as [`join`] — band join for
/// `Follows`/`FollowsTok` conjuncts, nested loop otherwise — with
/// predicates evaluated over [`JoinRow`] cursors and surviving pairs
/// copied column-wise. Output order is byte-identical to the row form.
pub fn join_batch(
    left: &TupleBatch,
    right: &TupleBatch,
    pred: &Expr,
    ctx: &EvalCtx<'_>,
) -> TupleBatch {
    let left_arity = left.num_columns();
    if let Some((lcol, rcol, band)) = band_window(pred, left_arity) {
        return band_join_batch(left, right, pred, lcol, rcol, band, ctx);
    }
    let mut out = TupleBatch::concat_layout(left, right);
    for li in 0..left.len() {
        for ri in 0..right.len() {
            let row = JoinRow {
                left: left.row(li),
                right: right.row(ri),
            };
            if pred.eval(&row, ctx).as_bool() {
                out.push_joined_row(left, li, right, ri);
            }
        }
    }
    out
}

fn band_join_batch(
    left: &TupleBatch,
    right: &TupleBatch,
    pred: &Expr,
    lcol: usize,
    rcol: usize,
    band: Band,
    ctx: &EvalCtx<'_>,
) -> TupleBatch {
    let mut out = TupleBatch::concat_layout(left, right);
    // sort right row indices by span begin at rcol — reading the span
    // column as a plain slice, no per-row value unwrapping
    let rspans = right.spans(rcol);
    let mut order: Vec<usize> = (0..right.len()).collect();
    order.sort_by_key(|&i| rspans[i].begin);
    let begins: Vec<u32> = order.iter().map(|&i| rspans[i].begin).collect();

    let mut cands: Vec<usize> = Vec::new();
    for li in 0..left.len() {
        let a = left.span_at(li, lcol);
        let (lo, hi) = match band {
            Band::Chars { min, max } => {
                (a.end.saturating_add(min), a.end.saturating_add(max))
            }
            Band::Toks { max } => {
                let idx = ctx.tokens.first_token_at_or_after(a.end);
                let upper = idx + max as usize + 1;
                let bound = ctx
                    .tokens
                    .tokens()
                    .get(upper)
                    .map(|t| t.span.end)
                    .unwrap_or(u32::MAX);
                (a.end, bound)
            }
        };
        let start = begins.partition_point(|&b| b < lo);
        // candidates in original right-input order, exactly like the row
        // form (downstream Consolidate's first-tuple-wins rule must not
        // depend on the join algorithm); the scratch Vec is reused across
        // left rows
        cands.clear();
        cands.extend(
            (start..begins.len())
                .take_while(|&k| begins[k] <= hi)
                .map(|k| order[k]),
        );
        cands.sort_unstable();
        for &ri in &cands {
            let row = JoinRow {
                left: left.row(li),
                right: right.row(ri),
            };
            if pred.eval(&row, ctx).as_bool() {
                out.push_joined_row(left, li, right, ri);
            }
        }
    }
    out
}

/// `Consolidate` (columnar): same first-occurrence-wins rule as
/// [`consolidate`], with the linear scan replaced by a span → first-row
/// index map.
pub fn consolidate_batch(
    input: &TupleBatch,
    col: usize,
    policy: ConsolidatePolicy,
) -> TupleBatch {
    let mut out = TupleBatch::like(input);
    if input.is_empty() {
        return out;
    }
    let spans = input.spans(col);
    let kept = consolidate_spans(spans, policy);
    let mut first: HashMap<Span, usize> = HashMap::with_capacity(spans.len());
    for (i, s) in spans.iter().enumerate() {
        first.entry(*s).or_insert(i);
    }
    for k in kept {
        if let Some(&i) = first.get(&k) {
            out.push_row_from(input, i);
        }
    }
    out
}

/// `Difference` (columnar): set semantics on whole rows, compared
/// column-wise without materializing values.
pub fn difference_batch(left: &TupleBatch, right: &TupleBatch) -> TupleBatch {
    let mut out = TupleBatch::like(left);
    let mut kept: Vec<usize> = Vec::new();
    for li in 0..left.len() {
        if (0..right.len()).any(|ri| TupleBatch::rows_equal(left, li, right, ri)) {
            continue;
        }
        if kept
            .iter()
            .any(|&k| TupleBatch::rows_equal(left, li, left, k))
        {
            continue;
        }
        kept.push(li);
        out.push_row_from(left, li);
    }
    out
}

/// `Block` (columnar): identical grouping to [`block`] over the span
/// column slice.
pub fn block_batch(
    input: &TupleBatch,
    col: usize,
    max_gap: u32,
    min_size: usize,
) -> TupleBatch {
    let mut spans: Vec<Span> = input.spans(col).to_vec();
    spans.sort();
    let mut out = TupleBatch::single_span();
    let mut i = 0;
    while i < spans.len() {
        let mut members = 1;
        let mut cover = spans[i];
        let mut j = i + 1;
        while j < spans.len() {
            let s = spans[j];
            if s.begin >= cover.end && s.begin - cover.end > max_gap {
                break;
            }
            cover = cover.combine(&s);
            members += 1;
            j += 1;
        }
        if members >= min_size {
            out.push_span(cover);
        }
        i = j;
    }
    out
}

/// `Sort` (columnar): stable index sort by key columns, then a column-wise
/// gather. Ordering mirrors [`cmp_values`] (nulls last).
pub fn sort_batch(input: &TupleBatch, keys: &[usize]) -> TupleBatch {
    let mut idx: Vec<usize> = (0..input.len()).collect();
    idx.sort_by(|&a, &b| {
        for &k in keys {
            let o = input.column(k).cmp_cells(a, input.column(k), b);
            if o != Ordering::Equal {
                return o;
            }
        }
        Ordering::Equal
    });
    let mut out = TupleBatch::like(input);
    for i in idx {
        out.push_row_from(input, i);
    }
    out
}

/// `Limit` (columnar): first `n` rows, copied column-wise.
pub fn limit_batch(input: &TupleBatch, n: usize) -> TupleBatch {
    let mut out = TupleBatch::like(input);
    for i in 0..n.min(input.len()) {
        out.push_row_from(input, i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::expr::{CmpOp, Func};
    use crate::text::Tokenizer;

    fn ctx(text: &'static str) -> EvalCtx<'static> {
        let tokens = Box::leak(Box::new(Tokenizer::standard().tokenize(text)));
        EvalCtx { text, tokens }
    }

    fn span_t(b: u32, e: u32) -> Tuple {
        vec![Value::Span(Span::new(b, e))]
    }

    #[test]
    fn doc_scan_covers_text() {
        let d = Document::new(0, "hello");
        assert_eq!(doc_scan(&d), vec![vec![Value::Span(Span::new(0, 5))]]);
    }

    #[test]
    fn select_filters() {
        let c = ctx("aaa bb c");
        let input = vec![span_t(0, 3), span_t(4, 6), span_t(7, 8)];
        let pred = Expr::Cmp(
            Box::new(Expr::Call(Func::GetLength, vec![Expr::Col(0)])),
            CmpOp::Ge,
            Box::new(Expr::LitInt(2)),
        );
        let out = select(&input, &pred, &c);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn project_computes() {
        let c = ctx("hello world");
        let input = vec![span_t(0, 5)];
        let cols = vec![
            (
                "len".to_string(),
                Expr::Call(Func::GetLength, vec![Expr::Col(0)]),
            ),
            (
                "txt".to_string(),
                Expr::Call(Func::GetText, vec![Expr::Col(0)]),
            ),
        ];
        let out = project(&input, &cols, &c);
        assert_eq!(out[0][0], Value::Int(5));
        assert_eq!(out[0][1], Value::Str("hello".into()));
    }

    #[test]
    fn join_cross_and_pred() {
        let c = ctx("aa bb cc dd");
        let left = vec![span_t(0, 2), span_t(6, 8)];
        let right = vec![span_t(3, 5), span_t(9, 11)];
        let pred = Expr::Call(
            Func::Follows,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(0), Expr::LitInt(1)],
        );
        let out = join(&left, &right, &pred, 1, &c);
        // (0,2)->(3,5) gap1 ok; (0,2)->(9,11) gap7 no; (6,8)->(9,11) gap1 ok;
        // (6,8)->(3,5) not follows
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
    }

    #[test]
    fn consolidate_keeps_first_tuple_per_span() {
        let input = vec![
            vec![Value::Span(Span::new(0, 10)), Value::Int(1)],
            vec![Value::Span(Span::new(2, 5)), Value::Int(2)],
            vec![Value::Span(Span::new(0, 10)), Value::Int(3)],
        ];
        let out = consolidate(&input, 0, ConsolidatePolicy::ContainedWithin);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0][1], Value::Int(1)); // first wins
    }

    #[test]
    fn sort_by_int_then_span() {
        let input = vec![
            vec![Value::Int(2), Value::Span(Span::new(5, 6))],
            vec![Value::Int(1), Value::Span(Span::new(9, 10))],
            vec![Value::Int(2), Value::Span(Span::new(1, 2))],
        ];
        let out = sort(&input, &[0, 1]);
        assert_eq!(out[0][0], Value::Int(1));
        assert_eq!(out[1][1], Value::Span(Span::new(1, 2)));
    }

    #[test]
    fn cmp_values_null_last() {
        assert_eq!(cmp_values(&Value::Null, &Value::Int(1)), Ordering::Greater);
        assert_eq!(cmp_values(&Value::Int(1), &Value::Null), Ordering::Less);
        assert_eq!(cmp_values(&Value::Null, &Value::Null), Ordering::Equal);
    }

    // -- columnar forms agree with the row forms, including output order --

    use crate::aog::FieldType;

    fn span_batch(pairs: &[(u32, u32)]) -> TupleBatch {
        let mut b = TupleBatch::single_span();
        for &(x, y) in pairs {
            b.push_span(Span::new(x, y));
        }
        b
    }

    #[test]
    fn select_batch_matches_rows() {
        let c = ctx("aaa bb c");
        let pairs = [(0, 3), (4, 6), (7, 8)];
        let rows: Vec<Tuple> = pairs.iter().map(|&(b, e)| span_t(b, e)).collect();
        let batch = span_batch(&pairs);
        let pred = Expr::Cmp(
            Box::new(Expr::Call(Func::GetLength, vec![Expr::Col(0)])),
            CmpOp::Ge,
            Box::new(Expr::LitInt(2)),
        );
        assert_eq!(
            select_batch(&batch, &pred, &c).to_tuples(),
            select(&rows, &pred, &c)
        );
    }

    #[test]
    fn project_batch_matches_rows() {
        let c = ctx("hello world");
        let rows = vec![span_t(0, 5)];
        let batch = span_batch(&[(0, 5)]);
        let cols = vec![
            (
                "len".to_string(),
                Expr::Call(Func::GetLength, vec![Expr::Col(0)]),
            ),
            (
                "txt".to_string(),
                Expr::Call(Func::GetText, vec![Expr::Col(0)]),
            ),
        ];
        let schema = Schema::of(&[("len", FieldType::Int), ("txt", FieldType::Str)]);
        assert_eq!(
            project_batch(&batch, &cols, &c, &schema).to_tuples(),
            project(&rows, &cols, &c)
        );
    }

    #[test]
    fn join_batch_matches_rows_band_and_nested() {
        let c = ctx("aa bb cc dd ee ff");
        let lp = [(0, 2), (6, 8), (12, 14)];
        let rp = [(3, 5), (9, 11), (15, 17)];
        let lrows: Vec<Tuple> = lp.iter().map(|&(b, e)| span_t(b, e)).collect();
        let rrows: Vec<Tuple> = rp.iter().map(|&(b, e)| span_t(b, e)).collect();
        let (lb, rb) = (span_batch(&lp), span_batch(&rp));
        // band-joinable predicate
        let band = Expr::Call(
            Func::Follows,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(0), Expr::LitInt(4)],
        );
        assert_eq!(
            join_batch(&lb, &rb, &band, &c).to_tuples(),
            join(&lrows, &rrows, &band, 1, &c)
        );
        // non-band predicate → nested loop in both
        let general = Expr::Call(Func::Overlaps, vec![Expr::Col(0), Expr::Col(1)]);
        assert_eq!(
            join_batch(&lb, &rb, &general, &c).to_tuples(),
            join(&lrows, &rrows, &general, 1, &c)
        );
    }

    #[test]
    fn consolidate_batch_keeps_first_row_per_span() {
        let rows = vec![
            vec![Value::Span(Span::new(0, 10)), Value::Int(1)],
            vec![Value::Span(Span::new(2, 5)), Value::Int(2)],
            vec![Value::Span(Span::new(0, 10)), Value::Int(3)],
        ];
        let schema = Schema::of(&[("m", FieldType::Span), ("n", FieldType::Int)]);
        let batch = TupleBatch::from_rows(&schema, &rows);
        assert_eq!(
            consolidate_batch(&batch, 0, ConsolidatePolicy::ContainedWithin).to_tuples(),
            consolidate(&rows, 0, ConsolidatePolicy::ContainedWithin)
        );
    }

    #[test]
    fn difference_sort_block_limit_batch_match_rows() {
        let schema = Schema::of(&[("m", FieldType::Span)]);
        let lrows: Vec<Tuple> = vec![span_t(0, 2), span_t(3, 5), span_t(0, 2), span_t(6, 9)];
        let rrows: Vec<Tuple> = vec![span_t(3, 5)];
        let lb = TupleBatch::from_rows(&schema, &lrows);
        let rb = TupleBatch::from_rows(&schema, &rrows);
        assert_eq!(
            difference_batch(&lb, &rb).to_tuples(),
            difference(&lrows, &rrows)
        );
        assert_eq!(sort_batch(&lb, &[0]).to_tuples(), sort(&lrows, &[0]));
        assert_eq!(block_batch(&lb, 0, 2, 2).to_tuples(), block(&lrows, 0, 2, 2));
        assert_eq!(
            limit_batch(&lb, 2).to_tuples(),
            lrows.iter().take(2).cloned().collect::<Vec<_>>()
        );
    }

    #[test]
    fn extraction_batches_match_rows() {
        let d = Document::new(0, "Alice met Bob at IBM Research today");
        let re = crate::regex::compile("[A-Z][a-z]+", false).unwrap();
        assert_eq!(
            regex_extract_batch(&re, &d).to_tuples(),
            regex_extract(&re, &d)
        );
        let ac = AhoCorasick::build(
            &["IBM".to_string(), "IBM Research".to_string()],
            crate::dict::CaseMode::Exact,
        );
        assert_eq!(
            dict_extract_batch(&ac, &d).to_tuples(),
            dict_extract(&ac, &d)
        );
        assert_eq!(doc_scan_batch(&d).to_tuples(), doc_scan(&d));
    }
}
