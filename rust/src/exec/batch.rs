//! Columnar tuple batches and the **return-to-origin sharded arena** —
//! the storage layer of both execution routes' hot paths.
//!
//! The seed executor materialized every operator output as `Vec<Tuple>`
//! with `Tuple = Vec<Value>`: one heap allocation per tuple per operator
//! per document, through a 16-byte-tagged enum even when a column is pure
//! spans. The paper's software baseline is supposed to be memory-bandwidth
//! bound, not allocator bound, so this module replaces that layout with:
//!
//! * [`TupleBatch`] — one buffer per *column*, typed ([`ColumnData`]:
//!   spans, ints, floats, bools, strings) plus a lazily-materialized null
//!   bitmap ([`NullMask`], absent in the common all-valid case). A batch
//!   of `n` span tuples is a single `Vec<Span>` instead of `n` boxed rows.
//! * The sharded arena — a small fixed set of process-level buffer pools
//!   ([`NUM_SHARDS`] mutex-striped freelists) fronted by per-thread
//!   caches. Every thread is *homed* on one shard ([`ArenaId`]; session
//!   workers and the accelerator's communication thread pin stable
//!   shards, everything else is assigned round-robin), checks buffers out
//!   of its home shard, and every checked-out buffer is **stamped** with
//!   its origin shard. On drop the buffer is routed **back to its
//!   origin** — same shard: pushed on the thread-local cache without a
//!   lock; different shard: one mutex push on the origin's freelist — so
//!   batches that cross threads (worker → communication thread
//!   submissions, reply batches, results collected elsewhere) refill the
//!   pools their *producers* draw from. Both the software route and the
//!   accelerated route therefore reach a steady state of **zero fresh
//!   buffer allocations per document**; [`shard_stats`] exposes the
//!   per-shard gauges that pin the invariant.
//! * [`TupleRef`] — a cursor over one row of a batch, implementing
//!   [`RowAccess`] so the scalar expression evaluator runs unchanged over
//!   both layouts; [`JoinRow`] concatenates two cursors for join
//!   predicates without materializing the combined row.
//!
//! Row-oriented `Tuple`s survive only at the API boundary:
//! [`DocResult`](super::DocResult) converts lazily on first access.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::aog::expr::RowAccess;
use crate::aog::{FieldType, Schema, Tuple, Value};
use crate::metrics::{ArenaShardSnapshot, ArenaSnapshot, BlockPoolSnapshot};
use crate::text::Span;

/// Typed storage for one column of a [`TupleBatch`].
#[derive(Debug, Clone)]
pub enum ColumnData {
    /// Span cells.
    Spans(Vec<Span>),
    /// Integer cells.
    Ints(Vec<i64>),
    /// Float cells.
    Floats(Vec<f64>),
    /// Boolean cells.
    Bools(Vec<bool>),
    /// String cells (interned).
    Strs(Vec<Arc<str>>),
}

impl ColumnData {
    /// Number of cells.
    pub fn len(&self) -> usize {
        match self {
            ColumnData::Spans(v) => v.len(),
            ColumnData::Ints(v) => v.len(),
            ColumnData::Floats(v) => v.len(),
            ColumnData::Bools(v) => v.len(),
            ColumnData::Strs(v) => v.len(),
        }
    }

    /// True when the column holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The column's declared type.
    pub fn field_type(&self) -> FieldType {
        match self {
            ColumnData::Spans(_) => FieldType::Span,
            ColumnData::Ints(_) => FieldType::Int,
            ColumnData::Floats(_) => FieldType::Float,
            ColumnData::Bools(_) => FieldType::Bool,
            ColumnData::Strs(_) => FieldType::Str,
        }
    }

    fn clear(&mut self) {
        match self {
            ColumnData::Spans(v) => v.clear(),
            ColumnData::Ints(v) => v.clear(),
            ColumnData::Floats(v) => v.clear(),
            ColumnData::Bools(v) => v.clear(),
            ColumnData::Strs(v) => v.clear(),
        }
    }

}

/// The shared empty-string placeholder null cells use — a refcount bump
/// instead of a per-null allocation.
fn empty_str() -> Arc<str> {
    static EMPTY: OnceLock<Arc<str>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from("")).clone()
}

/// Per-row null flags, packed 64 rows per word. Only allocated once a null
/// actually appears — extraction and the span algebra never produce one.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NullMask {
    words: Vec<u64>,
}

impl NullMask {
    fn set(&mut self, i: usize) {
        let w = i / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    fn get(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .is_some_and(|w| (w >> (i % 64)) & 1 == 1)
    }

    fn any(&self) -> bool {
        self.words.iter().any(|w| *w != 0)
    }
}

/// One typed column plus its (usually absent) null bitmap. The data
/// buffer is checked out of the calling thread's home arena shard and is
/// stamped with that shard's [`ArenaId`]; on drop it is routed back to
/// its **origin** shard, wherever the drop happens.
#[derive(Debug)]
pub struct Column {
    data: ColumnData,
    nulls: Option<NullMask>,
    /// The shard this column's data buffer was checked out of.
    origin: ArenaId,
}

impl Column {
    /// Checked-out empty column of type `ty`.
    fn new(ty: FieldType) -> Column {
        let (data, origin) = arena_take(ty);
        Column {
            data,
            nulls: None,
            origin,
        }
    }

    /// Number of cells.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the column holds no cells.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The column's declared type.
    pub fn field_type(&self) -> FieldType {
        self.data.field_type()
    }

    /// The typed storage.
    pub fn data(&self) -> &ColumnData {
        &self.data
    }

    /// True when cell `i` is null.
    #[inline]
    pub fn is_null(&self, i: usize) -> bool {
        self.nulls.as_ref().is_some_and(|m| m.get(i))
    }

    fn push_null(&mut self) {
        // a placeholder keeps the typed buffer dense; the mask records it
        match &mut self.data {
            ColumnData::Spans(v) => v.push(Span::new(0, 0)),
            ColumnData::Ints(v) => v.push(0),
            ColumnData::Floats(v) => v.push(0.0),
            ColumnData::Bools(v) => v.push(false),
            ColumnData::Strs(v) => v.push(empty_str()),
        }
        let i = self.data.len() - 1;
        self.nulls.get_or_insert_with(NullMask::default).set(i);
    }

    /// Append `v`; its kind must match the column type (or be null).
    pub fn push_value(&mut self, v: &Value) {
        if matches!(v, Value::Null) {
            self.push_null();
            return;
        }
        match (&mut self.data, v) {
            (ColumnData::Spans(d), Value::Span(s)) => d.push(*s),
            (ColumnData::Ints(d), Value::Int(x)) => d.push(*x),
            (ColumnData::Floats(d), Value::Float(x)) => d.push(*x),
            (ColumnData::Bools(d), Value::Bool(x)) => d.push(*x),
            (ColumnData::Strs(d), Value::Str(s)) => d.push(s.clone()),
            (d, v) => panic!("value {v:?} does not fit a {} column", d.field_type()),
        }
    }

    /// Append cell `i` of `src` (same column type) without going through
    /// `Value` — the row-copy primitive of select/consolidate/sort/limit.
    #[inline]
    pub fn push_cell(&mut self, src: &Column, i: usize) {
        if src.is_null(i) {
            self.push_null();
            return;
        }
        match (&mut self.data, &src.data) {
            (ColumnData::Spans(d), ColumnData::Spans(s)) => d.push(s[i]),
            (ColumnData::Ints(d), ColumnData::Ints(s)) => d.push(s[i]),
            (ColumnData::Floats(d), ColumnData::Floats(s)) => d.push(s[i]),
            (ColumnData::Bools(d), ColumnData::Bools(s)) => d.push(s[i]),
            (ColumnData::Strs(d), ColumnData::Strs(s)) => d.push(s[i].clone()),
            (d, s) => panic!(
                "column type mismatch: {} cell into {} column",
                s.field_type(),
                d.field_type()
            ),
        }
    }

    /// Append every cell of `src` (same column type) — the union primitive.
    pub fn extend_from(&mut self, src: &Column) {
        let base = self.data.len();
        match (&mut self.data, &src.data) {
            (ColumnData::Spans(d), ColumnData::Spans(s)) => d.extend_from_slice(s),
            (ColumnData::Ints(d), ColumnData::Ints(s)) => d.extend_from_slice(s),
            (ColumnData::Floats(d), ColumnData::Floats(s)) => d.extend_from_slice(s),
            (ColumnData::Bools(d), ColumnData::Bools(s)) => d.extend_from_slice(s),
            (ColumnData::Strs(d), ColumnData::Strs(s)) => d.extend_from_slice(s),
            (d, s) => panic!(
                "column type mismatch: extending {} column with {}",
                d.field_type(),
                s.field_type()
            ),
        }
        if let Some(src_nulls) = &src.nulls {
            if src_nulls.any() {
                let dst = self.nulls.get_or_insert_with(NullMask::default);
                for i in 0..src.data.len() {
                    if src_nulls.get(i) {
                        dst.set(base + i);
                    }
                }
            }
        }
    }

    /// Cell `i` as an owned [`Value`] (the API-boundary conversion).
    pub fn value(&self, i: usize) -> Value {
        if self.is_null(i) {
            return Value::Null;
        }
        match &self.data {
            ColumnData::Spans(v) => Value::Span(v[i]),
            ColumnData::Ints(v) => Value::Int(v[i]),
            ColumnData::Floats(v) => Value::Float(v[i]),
            ColumnData::Bools(v) => Value::Bool(v[i]),
            ColumnData::Strs(v) => Value::Str(v[i].clone()),
        }
    }

    /// Cell `i` as a span (panics on nulls or non-span columns, mirroring
    /// [`Value::as_span`]).
    #[inline]
    pub fn span(&self, i: usize) -> Span {
        match &self.data {
            ColumnData::Spans(v) if !self.is_null(i) => v[i],
            _ => panic!("expected span, got {:?}", self.value(i)),
        }
    }

    /// Total order over two cells, mirroring
    /// [`cmp_values`](super::operators::cmp_values): same-type natural
    /// order, nulls last, float ties resolved as equal.
    pub fn cmp_cells(&self, i: usize, other: &Column, j: usize) -> Ordering {
        match (self.is_null(i), other.is_null(j)) {
            (true, true) => return Ordering::Equal,
            (true, false) => return Ordering::Greater,
            (false, true) => return Ordering::Less,
            (false, false) => {}
        }
        match (&self.data, &other.data) {
            (ColumnData::Spans(a), ColumnData::Spans(b)) => a[i].cmp(&b[j]),
            (ColumnData::Ints(a), ColumnData::Ints(b)) => a[i].cmp(&b[j]),
            (ColumnData::Floats(a), ColumnData::Floats(b)) => {
                a[i].partial_cmp(&b[j]).unwrap_or(Ordering::Equal)
            }
            (ColumnData::Bools(a), ColumnData::Bools(b)) => a[i].cmp(&b[j]),
            (ColumnData::Strs(a), ColumnData::Strs(b)) => a[i].cmp(&b[j]),
            _ => Ordering::Equal, // mixed types cannot occur in a typed column
        }
    }

    /// Cell equality with [`Value`]'s `PartialEq` semantics (`NaN != NaN`,
    /// `Null == Null`) — the difference operator's set membership.
    pub fn eq_cells(&self, i: usize, other: &Column, j: usize) -> bool {
        match (self.is_null(i), other.is_null(j)) {
            (true, true) => return true,
            (true, false) | (false, true) => return false,
            (false, false) => {}
        }
        match (&self.data, &other.data) {
            (ColumnData::Spans(a), ColumnData::Spans(b)) => a[i] == b[j],
            (ColumnData::Ints(a), ColumnData::Ints(b)) => a[i] == b[j],
            (ColumnData::Floats(a), ColumnData::Floats(b)) => a[i] == b[j],
            (ColumnData::Bools(a), ColumnData::Bools(b)) => a[i] == b[j],
            (ColumnData::Strs(a), ColumnData::Strs(b)) => a[i] == b[j],
            _ => false,
        }
    }
}

impl Clone for Column {
    fn clone(&self) -> Column {
        // clones are arena-backed too, so results escaping into DocResults
        // keep recycling wherever they are eventually dropped
        let mut c = Column::new(self.data.field_type());
        c.extend_from(self);
        c
    }
}

impl Drop for Column {
    fn drop(&mut self) {
        let data = std::mem::replace(&mut self.data, ColumnData::Bools(Vec::new()));
        arena_recycle(data, self.origin);
    }
}

/// A columnar batch of tuples: one [`Column`] per schema field, all the
/// same length. The executor's operators consume and produce these; rows
/// exist only as [`TupleRef`] cursors until the API boundary converts.
#[derive(Debug)]
pub struct TupleBatch {
    columns: Vec<Column>,
    len: usize,
    /// The shard the batch's column *container* was checked out of (each
    /// [`Column`] carries its own origin independently).
    origin: ArenaId,
}

impl TupleBatch {
    /// Empty batch with one checked-out column per field of `schema`.
    pub fn for_schema(schema: &Schema) -> TupleBatch {
        let (mut columns, origin) = arena_take_columns();
        columns.extend(schema.fields.iter().map(|f| Column::new(f.ty)));
        TupleBatch {
            columns,
            len: 0,
            origin,
        }
    }

    /// Empty batch with the same column layout as `src`.
    pub fn like(src: &TupleBatch) -> TupleBatch {
        let (mut columns, origin) = arena_take_columns();
        columns.extend(src.columns.iter().map(|c| Column::new(c.field_type())));
        TupleBatch {
            columns,
            len: 0,
            origin,
        }
    }

    /// Empty batch whose layout is `left`'s columns followed by `right`'s
    /// — the join output shape.
    pub fn concat_layout(left: &TupleBatch, right: &TupleBatch) -> TupleBatch {
        let (mut columns, origin) = arena_take_columns();
        columns.extend(
            left.columns
                .iter()
                .chain(&right.columns)
                .map(|c| Column::new(c.field_type())),
        );
        TupleBatch {
            columns,
            len: 0,
            origin,
        }
    }

    /// Empty single-span-column batch — the shape of every extraction
    /// leaf, `DocScan` and `Block`.
    pub fn single_span() -> TupleBatch {
        let (mut columns, origin) = arena_take_columns();
        columns.push(Column::new(FieldType::Span));
        TupleBatch {
            columns,
            len: 0,
            origin,
        }
    }

    /// Zero-column, zero-row batch.
    pub fn empty() -> TupleBatch {
        let (columns, origin) = arena_take_columns();
        TupleBatch {
            columns,
            len: 0,
            origin,
        }
    }

    /// Convert a row-oriented view (the legacy layout) into a batch.
    pub fn from_rows(schema: &Schema, rows: &[Tuple]) -> TupleBatch {
        let mut b = TupleBatch::for_schema(schema);
        for t in rows {
            b.push_tuple(t);
        }
        b
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the batch holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of columns.
    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    /// Column `i`.
    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    /// The span cells of column `col` as a plain slice — the fast path for
    /// band joins, consolidate and block, which read a whole span column.
    /// Panics if the column is not spans or contains a null (mirroring the
    /// per-row [`Value::as_span`] contract).
    pub fn spans(&self, col: usize) -> &[Span] {
        let c = &self.columns[col];
        assert!(
            !c.nulls.as_ref().is_some_and(|m| m.any()),
            "expected span, got null"
        );
        match &c.data {
            ColumnData::Spans(v) => v,
            other => panic!("expected span column, got {}", other.field_type()),
        }
    }

    /// Span cell at (`row`, `col`).
    #[inline]
    pub fn span_at(&self, row: usize, col: usize) -> Span {
        self.columns[col].span(row)
    }

    /// Owned [`Value`] at (`row`, `col`).
    pub fn value(&self, row: usize, col: usize) -> Value {
        self.columns[col].value(row)
    }

    /// Cursor over row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> TupleRef<'_> {
        debug_assert!(i < self.len);
        TupleRef { batch: self, row: i }
    }

    /// Iterate all rows as cursors.
    pub fn rows(&self) -> impl Iterator<Item = TupleRef<'_>> {
        (0..self.len).map(move |i| self.row(i))
    }

    /// Append one row of owned values (must match the column count).
    pub fn push_row<I: IntoIterator<Item = Value>>(&mut self, vals: I) {
        let mut n = 0;
        for (i, v) in vals.into_iter().enumerate() {
            self.columns[i].push_value(&v);
            n += 1;
        }
        debug_assert_eq!(n, self.columns.len(), "row arity mismatch");
        self.len += 1;
    }

    /// Append one legacy row.
    pub fn push_tuple(&mut self, t: &Tuple) {
        debug_assert_eq!(t.len(), self.columns.len(), "row arity mismatch");
        for (c, v) in self.columns.iter_mut().zip(t) {
            c.push_value(v);
        }
        self.len += 1;
    }

    /// Append row `row` of `src` (same layout).
    #[inline]
    pub fn push_row_from(&mut self, src: &TupleBatch, row: usize) {
        for (dst, s) in self.columns.iter_mut().zip(&src.columns) {
            dst.push_cell(s, row);
        }
        self.len += 1;
    }

    /// Append the concatenation of `left[li]` and `right[ri]` (layout from
    /// [`TupleBatch::concat_layout`]) — the join emit primitive.
    #[inline]
    pub fn push_joined_row(
        &mut self,
        left: &TupleBatch,
        li: usize,
        right: &TupleBatch,
        ri: usize,
    ) {
        let la = left.columns.len();
        for (k, dst) in self.columns.iter_mut().enumerate() {
            if k < la {
                dst.push_cell(&left.columns[k], li);
            } else {
                dst.push_cell(&right.columns[k - la], ri);
            }
        }
        self.len += 1;
    }

    /// Append a one-span row (single-span-column batches only).
    #[inline]
    pub fn push_span(&mut self, s: Span) {
        debug_assert_eq!(self.columns.len(), 1);
        match &mut self.columns[0].data {
            ColumnData::Spans(v) => v.push(s),
            other => panic!("push_span on a {} column", other.field_type()),
        }
        self.len += 1;
    }

    /// Append every row of `other` (same layout) — the union primitive.
    pub fn extend_from(&mut self, other: &TupleBatch) {
        debug_assert_eq!(self.columns.len(), other.columns.len());
        for (dst, s) in self.columns.iter_mut().zip(&other.columns) {
            dst.extend_from(s);
        }
        self.len += other.len;
    }

    /// Hand the single span column's buffer to `f` for direct filling —
    /// how extraction leaves (and the accelerator's span reconstruction)
    /// emit matches straight into arena-backed column storage with no
    /// intermediate per-match values. The batch must be empty; its length
    /// becomes whatever `f` pushed.
    pub fn fill_spans<F: FnOnce(&mut Vec<Span>)>(&mut self, f: F) {
        assert_eq!(self.len, 0, "fill_spans on a non-empty batch");
        assert_eq!(self.columns.len(), 1, "fill_spans needs a single column");
        match &mut self.columns[0].data {
            ColumnData::Spans(v) => {
                f(v);
                self.len = v.len();
            }
            other => panic!("fill_spans on a {} column", other.field_type()),
        }
    }

    /// Row equality across batches of the same layout (the `Difference`
    /// operator's membership test), with [`Value`] `PartialEq` semantics.
    pub fn rows_equal(a: &TupleBatch, ai: usize, b: &TupleBatch, bi: usize) -> bool {
        debug_assert_eq!(a.columns.len(), b.columns.len());
        a.columns
            .iter()
            .zip(&b.columns)
            .all(|(ca, cb)| ca.eq_cells(ai, cb, bi))
    }

    /// Materialize the legacy row layout (API boundary only).
    pub fn to_tuples(&self) -> Vec<Tuple> {
        (0..self.len)
            .map(|i| self.columns.iter().map(|c| c.value(i)).collect())
            .collect()
    }
}

impl Clone for TupleBatch {
    fn clone(&self) -> TupleBatch {
        // clones check out of the CLONING thread's home shard: a worker
        // cloning a reply batch owns the copy outright, while the
        // original's buffers keep their origin stamp
        let (mut columns, origin) = arena_take_columns();
        columns.extend(self.columns.iter().cloned());
        TupleBatch {
            columns,
            len: self.len,
            origin,
        }
    }
}

impl Drop for TupleBatch {
    fn drop(&mut self) {
        // drop the columns first (each routes its data buffer back to its
        // origin shard), then send the emptied container home too
        self.columns.clear();
        arena_recycle_columns(std::mem::take(&mut self.columns), self.origin);
    }
}

/// A cursor over one row of a [`TupleBatch`]. Implements [`RowAccess`], so
/// predicates and projections evaluate against it directly.
#[derive(Clone, Copy)]
pub struct TupleRef<'a> {
    batch: &'a TupleBatch,
    row: usize,
}

impl TupleRef<'_> {
    /// Number of columns.
    pub fn arity(&self) -> usize {
        self.batch.columns.len()
    }

    /// Owned value of column `col`.
    #[inline]
    pub fn value(&self, col: usize) -> Value {
        self.batch.columns[col].value(self.row)
    }

    /// Span of column `col` (panics on non-span/null).
    #[inline]
    pub fn span(&self, col: usize) -> Span {
        self.batch.columns[col].span(self.row)
    }

    /// Materialize the row as a legacy [`Tuple`].
    pub fn to_tuple(&self) -> Tuple {
        (0..self.arity()).map(|c| self.value(c)).collect()
    }
}

impl RowAccess for TupleRef<'_> {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        self.value(i)
    }
}

/// Two row cursors seen as one concatenated row — how join predicates
/// evaluate over a candidate pair without building the combined tuple.
#[derive(Clone, Copy)]
pub struct JoinRow<'a> {
    /// Cursor over the left input's row.
    pub left: TupleRef<'a>,
    /// Cursor over the right input's row.
    pub right: TupleRef<'a>,
}

impl RowAccess for JoinRow<'_> {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        let la = self.left.arity();
        if i < la {
            self.left.value(i)
        } else {
            self.right.value(i - la)
        }
    }
}

// ---------------------------------------------------------------------------
// The return-to-origin sharded arena.
//
// Ownership model: a buffer belongs to the shard it was checked out of,
// forever. Threads check out of their HOME shard only (thread-local cache
// first, then the shard freelist, then a fresh allocation), but may drop
// buffers from any shard — the drop routes the buffer back to its origin.
// Supply therefore always returns to meet demand: the communication
// thread releasing a worker's submission batches refills that worker's
// shard, and a worker releasing the communication thread's reply batches
// refills the communication shard, so BOTH execution routes stop
// allocating once warm.

/// Number of global arena shards. The top [`MAX_COMM_SHARDS`] shards are
/// reserved for accelerator communication threads ([`ArenaId::comm_for`]);
/// session workers map onto the rest by worker index
/// ([`ArenaId::for_worker`]), and unpinned threads are spread round-robin.
/// Sharing a shard is always correct — it only adds freelist contention.
pub const NUM_SHARDS: usize = 16;

/// Reserved communication shards — one per accelerator device, so a
/// pool's reply batches return to the device thread that produced them.
/// Pools larger than this wrap ([`ArenaId::comm_for`]), which is correct
/// but shares a freelist between the wrapped devices.
pub const MAX_COMM_SHARDS: usize = 4;

/// Worker shards — everything below the reserved communication shards.
/// [`ArenaId::for_worker`] maps worker `w` to shard `w % WORKER_SHARDS`,
/// so worker shards occupy `0..WORKER_SHARDS` and communication shards
/// occupy `WORKER_SHARDS..NUM_SHARDS` — disjoint by construction,
/// asserted below.
pub const WORKER_SHARDS: usize = NUM_SHARDS - MAX_COMM_SHARDS;

// The shard map only works if the reserved communication range is
// non-empty and leaves room for workers; comm_for(d) descends from
// NUM_SHARDS - 1 and must never reach a worker shard.
const _: () = assert!(MAX_COMM_SHARDS > 0, "need at least one comm shard");
const _: () = assert!(
    NUM_SHARDS > MAX_COMM_SHARDS,
    "workers need at least one shard"
);
const _: () = assert!(
    NUM_SHARDS - 1 - (MAX_COMM_SHARDS - 1) >= WORKER_SHARDS,
    "comm shards must not collide with worker shards"
);

/// Upper bound of cached buffers per type in one thread-local cache —
/// large enough to cover every live node slot of a big merged catalog,
/// so a warmed worker's whole per-document working set recycles without
/// touching the shard mutex.
const LOCAL_MAX: usize = 256;

/// Upper bound of pooled buffers per type in one shard's global
/// freelist. Returns beyond the cap free the buffer (bounded memory).
const SHARD_MAX: usize = 512;

/// Package byte blocks are `STREAMS × block` i32 buffers — 256 KiB each
/// at the default block size, so they get far smaller caps than column
/// buffers: steady state needs two per communication thread (one being
/// filled, one in flight).
const BLOCK_LOCAL_MAX: usize = 4;

/// Shard-freelist cap for package byte blocks (see [`BLOCK_LOCAL_MAX`]).
const BLOCK_SHARD_MAX: usize = 8;

/// Stable identity of one arena shard — stamped into every checked-out
/// [`TupleBatch`]/[`Column`] buffer so `Drop` can route it home.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaId(u16);

impl ArenaId {
    /// The shard session worker `w` pins ([`pin_thread`]): stable across
    /// sessions, so a new session's worker pool re-uses the buffers the
    /// previous session's workers returned.
    pub fn for_worker(w: usize) -> ArenaId {
        ArenaId((w % WORKER_SHARDS) as u16)
    }

    /// The shard reserved for accelerator communication threads, kept
    /// apart from the worker shards so package post-processing never
    /// contends with worker checkouts. Equivalent to
    /// [`ArenaId::comm_for`]`(0)` — the single-device shard.
    pub fn comm() -> ArenaId {
        ArenaId::comm_for(0)
    }

    /// The communication shard for pool device `d`. Device 0 gets the
    /// historical [`ArenaId::comm`] shard (`NUM_SHARDS - 1`); devices
    /// beyond [`MAX_COMM_SHARDS`] wrap onto the same reserved shards,
    /// which only shares a freelist — never a correctness hazard.
    pub fn comm_for(d: usize) -> ArenaId {
        ArenaId((NUM_SHARDS - 1 - (d % MAX_COMM_SHARDS)) as u16)
    }

    /// This id's shard index (`0..NUM_SHARDS`).
    pub fn shard(self) -> usize {
        self.0 as usize
    }
}

/// One set of typed freelists — the shape shared by the shard-global
/// pools and the thread-local caches.
#[derive(Debug, Default)]
struct Pools {
    spans: Vec<Vec<Span>>,
    ints: Vec<Vec<i64>>,
    floats: Vec<Vec<f64>>,
    bools: Vec<Vec<bool>>,
    strs: Vec<Vec<Arc<str>>>,
    columns: Vec<Vec<Column>>,
    /// Package byte blocks (`accel::packing`): a different currency from
    /// the column buffers, pooled beside them so the communication
    /// thread's whole working set rides one arena. Excluded from
    /// [`Pools::count`] (which feeds the column-buffer gauges);
    /// [`block_pool_stats`] reports these separately.
    blocks: Vec<Vec<i32>>,
}

impl Pools {
    fn take(&mut self, ty: FieldType) -> Option<ColumnData> {
        match ty {
            FieldType::Span => self.spans.pop().map(ColumnData::Spans),
            FieldType::Int => self.ints.pop().map(ColumnData::Ints),
            FieldType::Float => self.floats.pop().map(ColumnData::Floats),
            FieldType::Bool => self.bools.pop().map(ColumnData::Bools),
            FieldType::Str => self.strs.pop().map(ColumnData::Strs),
        }
    }

    /// Park `data` (already cleared) unless the per-type list is at
    /// `cap`; a rejected buffer is handed back for the caller to free or
    /// overflow elsewhere.
    ///
    /// Zero-capacity buffers are pooled too: a column that stays empty
    /// all run still checks a buffer out per document, and a pool miss
    /// counts as `fresh` — supply must match demand or the steady-state
    /// invariant would fail on never-matching columns.
    fn put(&mut self, data: ColumnData, cap: usize) -> Option<ColumnData> {
        match data {
            ColumnData::Spans(v) if self.spans.len() < cap => self.spans.push(v),
            ColumnData::Ints(v) if self.ints.len() < cap => self.ints.push(v),
            ColumnData::Floats(v) if self.floats.len() < cap => self.floats.push(v),
            ColumnData::Bools(v) if self.bools.len() < cap => self.bools.push(v),
            ColumnData::Strs(v) if self.strs.len() < cap => self.strs.push(v),
            full => return Some(full),
        }
        None
    }

    /// Buffers parked across the five typed lists (column containers
    /// excluded, matching the original per-thread gauge).
    fn count(&self) -> usize {
        self.spans.len() + self.ints.len() + self.floats.len() + self.bools.len() + self.strs.len()
    }

    /// Move everything from `src` into `self` up to `cap` per type,
    /// freeing the overflow — how a dying thread's local cache drains
    /// into its home shard.
    fn absorb(&mut self, src: &mut Pools, cap: usize) {
        fn move_up_to<T>(dst: &mut Vec<T>, src: &mut Vec<T>, cap: usize) {
            while dst.len() < cap {
                match src.pop() {
                    Some(v) => dst.push(v),
                    None => break,
                }
            }
            src.clear(); // free the overflow
        }
        move_up_to(&mut self.spans, &mut src.spans, cap);
        move_up_to(&mut self.ints, &mut src.ints, cap);
        move_up_to(&mut self.floats, &mut src.floats, cap);
        move_up_to(&mut self.bools, &mut src.bools, cap);
        move_up_to(&mut self.strs, &mut src.strs, cap);
        move_up_to(&mut self.columns, &mut src.columns, cap);
        move_up_to(&mut self.blocks, &mut src.blocks, BLOCK_SHARD_MAX);
    }
}

/// One global shard: a mutex-striped freelist plus its gauges. The
/// counters are plain atomics so snapshots never take the pool lock on
/// the hot path's behalf.
#[derive(Debug, Default)]
struct Shard {
    pools: Mutex<Pools>,
    checkouts: AtomicU64,
    fresh: AtomicU64,
    returns_local: AtomicU64,
    returns_cross: AtomicU64,
    // package byte-block traffic, kept off the column gauges (and off
    // ArenaShardSnapshot, whose shape existing tests pin)
    block_checkouts: AtomicU64,
    block_fresh: AtomicU64,
    block_returns: AtomicU64,
}

fn shards() -> &'static [Shard] {
    static SHARDS: OnceLock<Vec<Shard>> = OnceLock::new();
    SHARDS.get_or_init(|| (0..NUM_SHARDS).map(|_| Shard::default()).collect())
}

/// The per-thread front of the arena: a home shard plus a lock-free cache
/// of home-origin buffers. Checkout order is cache → home shard freelist
/// → fresh allocation; returns of home-origin buffers go to the cache,
/// returns of foreign buffers go straight to their origin shard.
struct LocalArena {
    home: ArenaId,
    cache: Pools,
    /// Buffer checkouts performed by this thread.
    checkouts: u64,
    /// Checkouts by this thread that had to allocate fresh.
    fresh: u64,
}

impl LocalArena {
    fn new() -> LocalArena {
        // unpinned threads (tests, main, ad-hoc std::thread workers) are
        // spread round-robin over the worker shards
        static NEXT_HOME: AtomicUsize = AtomicUsize::new(0);
        LocalArena {
            home: ArenaId::for_worker(NEXT_HOME.fetch_add(1, AtomicOrdering::Relaxed)),
            cache: Pools::default(),
            checkouts: 0,
            fresh: 0,
        }
    }

    fn take(&mut self, ty: FieldType) -> (ColumnData, ArenaId) {
        self.checkouts += 1;
        if let Some(d) = self.cache.take(ty) {
            // the common steady-state path: no lock, no shared atomics —
            // cache hits are visible in the per-thread ArenaStats only
            return (d, self.home);
        }
        let shard = &shards()[self.home.shard()];
        shard.checkouts.fetch_add(1, AtomicOrdering::Relaxed);
        if let Some(d) = shard.pools.lock().unwrap().take(ty) {
            return (d, self.home);
        }
        self.fresh += 1;
        shard.fresh.fetch_add(1, AtomicOrdering::Relaxed);
        (fresh_data(ty), self.home)
    }

    fn put(&mut self, data: ColumnData, origin: ArenaId) {
        let shard = &shards()[origin.shard()];
        if origin == self.home {
            shard.returns_local.fetch_add(1, AtomicOrdering::Relaxed);
            if let Some(rejected) = self.cache.put(data, LOCAL_MAX) {
                // local cache full: overflow into the home freelist
                let _ = shard.pools.lock().unwrap().put(rejected, SHARD_MAX);
            }
        } else {
            // return-to-origin: one mutex push on the owning shard
            shard.returns_cross.fetch_add(1, AtomicOrdering::Relaxed);
            let _ = shard.pools.lock().unwrap().put(data, SHARD_MAX);
        }
    }

    fn take_columns(&mut self) -> (Vec<Column>, ArenaId) {
        if let Some(v) = self.cache.columns.pop() {
            return (v, self.home);
        }
        let shard = &shards()[self.home.shard()];
        let pooled = shard.pools.lock().unwrap().columns.pop();
        (pooled.unwrap_or_default(), self.home)
    }

    fn take_block(&mut self, len: usize) -> Vec<i32> {
        let shard = &shards()[self.home.shard()];
        shard.block_checkouts.fetch_add(1, AtomicOrdering::Relaxed);
        let mut b = match self.cache.blocks.pop() {
            Some(b) => b,
            None => match shard.pools.lock().unwrap().blocks.pop() {
                Some(b) => b,
                None => {
                    shard.block_fresh.fetch_add(1, AtomicOrdering::Relaxed);
                    Vec::new()
                }
            },
        };
        // packing relies on zero-initialization for the NUL document
        // separators and tail padding, so a recycled block is re-zeroed:
        // a memset when its capacity suffices, one realloc when the
        // adaptive block size outgrew it
        b.clear();
        b.resize(len, 0);
        b
    }

    fn put_block(&mut self, mut b: Vec<i32>) {
        if b.capacity() == 0 {
            return; // nothing was ever allocated; pooling it gains nothing
        }
        b.clear();
        let shard = &shards()[self.home.shard()];
        shard.block_returns.fetch_add(1, AtomicOrdering::Relaxed);
        if self.cache.blocks.len() < BLOCK_LOCAL_MAX {
            self.cache.blocks.push(b);
            return;
        }
        let mut pools = shard.pools.lock().unwrap();
        if pools.blocks.len() < BLOCK_SHARD_MAX {
            pools.blocks.push(b);
        }
    }

    fn put_columns(&mut self, v: Vec<Column>, origin: ArenaId) {
        debug_assert!(v.is_empty());
        if v.capacity() == 0 {
            return; // nothing was ever allocated; pooling it gains nothing
        }
        if origin == self.home && self.cache.columns.len() < LOCAL_MAX {
            self.cache.columns.push(v);
            return;
        }
        let pools = &mut *shards()[origin.shard()].pools.lock().unwrap();
        if pools.columns.len() < SHARD_MAX {
            pools.columns.push(v);
        }
    }
}

impl Drop for LocalArena {
    fn drop(&mut self) {
        // thread exit: drain the local cache into the home shard so the
        // next thread homed here (e.g. the same worker index of the next
        // session) inherits the warm buffers
        let mut cache = std::mem::take(&mut self.cache);
        shards()[self.home.shard()]
            .pools
            .lock()
            .unwrap()
            .absorb(&mut cache, SHARD_MAX);
    }
}

fn fresh_data(ty: FieldType) -> ColumnData {
    match ty {
        FieldType::Span => ColumnData::Spans(Vec::new()),
        FieldType::Int => ColumnData::Ints(Vec::new()),
        FieldType::Float => ColumnData::Floats(Vec::new()),
        FieldType::Bool => ColumnData::Bools(Vec::new()),
        FieldType::Str => ColumnData::Strs(Vec::new()),
    }
}

/// Gauges of the calling thread's view of the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffer checkouts performed by this thread.
    pub checkouts: u64,
    /// Checkouts by this thread that had to allocate a fresh buffer
    /// (both the local cache and the home shard freelist were empty).
    /// After warm-up this stops growing — the recycling invariant the
    /// `bench-alloc` tests pin.
    pub fresh: u64,
    /// Buffers currently parked in this thread's local cache plus its
    /// home shard's freelist.
    pub pooled: usize,
}

thread_local! {
    static ARENA: RefCell<LocalArena> = RefCell::new(LocalArena::new());
}

/// Home the calling thread on shard `id`, flushing any previously cached
/// buffers to the old home first. Session workers call this with
/// [`ArenaId::for_worker`] and the accelerator communication thread with
/// [`ArenaId::comm`], so pool placement is stable across session
/// restarts; everything else keeps its round-robin default.
pub fn pin_thread(id: ArenaId) {
    let _ = ARENA.try_with(|a| {
        let mut a = a.borrow_mut();
        if a.home != id {
            let mut cache = std::mem::take(&mut a.cache);
            shards()[a.home.shard()]
                .pools
                .lock()
                .unwrap()
                .absorb(&mut cache, SHARD_MAX);
            a.home = id;
        }
    });
}

fn arena_take(ty: FieldType) -> (ColumnData, ArenaId) {
    ARENA
        .try_with(|a| a.borrow_mut().take(ty))
        // thread teardown: the local arena is gone; allocate plainly and
        // stamp shard 0 so the eventual drop still parks the buffer
        .unwrap_or_else(|_| (fresh_data(ty), ArenaId::for_worker(0)))
}

fn arena_recycle(mut data: ColumnData, origin: ArenaId) {
    // clear before routing: for string columns this releases the Arc
    // references immediately instead of pinning document text in a pool
    data.clear();
    let mut slot = Some(data);
    let alive = ARENA.try_with(|a| {
        a.borrow_mut().put(slot.take().expect("routed once"), origin);
    });
    if alive.is_err() {
        // thread teardown: route straight to the origin shard (a static,
        // still very much alive), counted as a cross-thread return
        if let Some(data) = slot.take() {
            let shard = &shards()[origin.shard()];
            shard.returns_cross.fetch_add(1, AtomicOrdering::Relaxed);
            let _ = shard.pools.lock().unwrap().put(data, SHARD_MAX);
        }
    }
}

fn arena_take_columns() -> (Vec<Column>, ArenaId) {
    ARENA
        .try_with(|a| a.borrow_mut().take_columns())
        .unwrap_or_else(|_| (Vec::new(), ArenaId::for_worker(0)))
}

fn arena_recycle_columns(v: Vec<Column>, origin: ArenaId) {
    let mut slot = Some(v);
    let alive = ARENA.try_with(|a| {
        a.borrow_mut()
            .put_columns(slot.take().expect("routed once"), origin);
    });
    if alive.is_err() {
        if let Some(v) = slot.take() {
            if v.capacity() > 0 {
                let pools = &mut *shards()[origin.shard()].pools.lock().unwrap();
                if pools.columns.len() < SHARD_MAX {
                    pools.columns.push(v);
                }
            }
        }
    }
}

/// Check a zeroed `len`-element package byte block out of the calling
/// thread's arena (cache → home shard pool → fresh allocation). Blocks
/// carry no origin stamp: checkout and return both happen on the
/// accelerator's communication thread in steady state, so returns go to
/// the *caller's* home shard — supply still meets demand, and a block
/// released on a foreign thread just warms that thread's pool instead.
pub fn take_block(len: usize) -> Vec<i32> {
    ARENA
        .try_with(|a| a.borrow_mut().take_block(len))
        .unwrap_or_else(|_| {
            // thread teardown: the local arena is gone; allocate plainly
            let shard = &shards()[ArenaId::comm().shard()];
            shard.block_checkouts.fetch_add(1, AtomicOrdering::Relaxed);
            shard.block_fresh.fetch_add(1, AtomicOrdering::Relaxed);
            vec![0i32; len]
        })
}

/// Return a package byte block to the calling thread's arena (see
/// [`take_block`]). Contents are discarded; the next checkout re-zeroes.
pub fn recycle_block(b: Vec<i32>) {
    let mut slot = Some(b);
    let alive = ARENA.try_with(|a| {
        a.borrow_mut().put_block(slot.take().expect("routed once"));
    });
    if alive.is_err() {
        if let Some(mut b) = slot.take() {
            if b.capacity() == 0 {
                return;
            }
            b.clear();
            let shard = &shards()[ArenaId::comm().shard()];
            shard.block_returns.fetch_add(1, AtomicOrdering::Relaxed);
            let mut pools = shard.pools.lock().unwrap();
            if pools.blocks.len() < BLOCK_SHARD_MAX {
                pools.blocks.push(b);
            }
        }
    }
}

/// Process-wide package byte-block pool totals (all shards summed) —
/// the `bench-alloc` gauge proving package assembly stops allocating
/// after warm-up, reported beside the column-buffer [`ArenaSnapshot`].
pub fn block_pool_stats() -> BlockPoolSnapshot {
    let mut t = BlockPoolSnapshot::default();
    for s in shards() {
        t.checkouts += s.block_checkouts.load(AtomicOrdering::Relaxed);
        t.fresh += s.block_fresh.load(AtomicOrdering::Relaxed);
        t.returns += s.block_returns.load(AtomicOrdering::Relaxed);
        t.pooled += s.pools.lock().unwrap().blocks.len();
    }
    t
}

/// Snapshot the calling thread's arena gauges ([`ArenaStats`]): its own
/// checkout/fresh counters plus the buffers parked in its local cache and
/// home shard.
pub fn arena_stats() -> ArenaStats {
    ARENA
        .try_with(|a| {
            let a = a.borrow();
            let shard_pooled = shards()[a.home.shard()].pools.lock().unwrap().count();
            ArenaStats {
                checkouts: a.checkouts,
                fresh: a.fresh,
                pooled: a.cache.count() + shard_pooled,
            }
        })
        .unwrap_or(ArenaStats {
            checkouts: 0,
            fresh: 0,
            pooled: 0,
        })
}

/// Snapshot every shard's gauges, in shard order — the process-level view
/// of checkout/fresh/return traffic (`repro bench` reports these, and the
/// accelerated-path steady-state tests assert on them).
pub fn shard_stats() -> Vec<ArenaShardSnapshot> {
    shards()
        .iter()
        .enumerate()
        .map(|(i, s)| ArenaShardSnapshot {
            shard: i,
            checkouts: s.checkouts.load(AtomicOrdering::Relaxed),
            fresh: s.fresh.load(AtomicOrdering::Relaxed),
            returns_local: s.returns_local.load(AtomicOrdering::Relaxed),
            returns_cross: s.returns_cross.load(AtomicOrdering::Relaxed),
            pooled: s.pools.lock().unwrap().count(),
        })
        .collect()
}

/// Process-wide arena totals (all shards summed).
pub fn global_arena_stats() -> ArenaSnapshot {
    ArenaSnapshot::from_shards(&shard_stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::FieldType;

    fn schema() -> Schema {
        Schema::of(&[
            ("m", FieldType::Span),
            ("n", FieldType::Int),
            ("s", FieldType::Str),
        ])
    }

    #[test]
    fn roundtrip_rows_to_batch_and_back() {
        let rows: Vec<Tuple> = vec![
            vec![
                Value::Span(Span::new(0, 3)),
                Value::Int(7),
                Value::Str("a".into()),
            ],
            vec![Value::Span(Span::new(4, 6)), Value::Null, Value::Str("b".into())],
        ];
        let b = TupleBatch::from_rows(&schema(), &rows);
        assert_eq!(b.len(), 2);
        assert_eq!(b.num_columns(), 3);
        assert_eq!(b.to_tuples(), rows);
        assert!(b.column(1).is_null(1));
        assert!(!b.column(1).is_null(0));
        assert_eq!(b.value(0, 1), Value::Int(7));
        assert_eq!(b.value(1, 1), Value::Null);
    }

    #[test]
    fn row_cursor_and_join_row() {
        let rows: Vec<Tuple> = vec![vec![Value::Span(Span::new(1, 2)), Value::Int(5), Value::Str("x".into())]];
        let b = TupleBatch::from_rows(&schema(), &rows);
        let r = b.row(0);
        assert_eq!(r.arity(), 3);
        assert_eq!(r.span(0), Span::new(1, 2));
        assert_eq!(r.value_at(1), Value::Int(5));
        assert_eq!(r.to_tuple(), rows[0]);

        let j = JoinRow { left: b.row(0), right: b.row(0) };
        assert_eq!(j.value_at(0), Value::Span(Span::new(1, 2)));
        assert_eq!(j.value_at(4), Value::Int(5));
    }

    #[test]
    fn push_joined_row_concatenates() {
        let left = TupleBatch::from_rows(
            &Schema::of(&[("a", FieldType::Span)]),
            &[vec![Value::Span(Span::new(0, 1))]],
        );
        let right = TupleBatch::from_rows(
            &Schema::of(&[("b", FieldType::Int)]),
            &[vec![Value::Int(9)]],
        );
        let mut out = TupleBatch::concat_layout(&left, &right);
        out.push_joined_row(&left, 0, &right, 0);
        assert_eq!(
            out.to_tuples(),
            vec![vec![Value::Span(Span::new(0, 1)), Value::Int(9)]]
        );
    }

    #[test]
    fn fill_spans_direct_emit() {
        let mut b = TupleBatch::single_span();
        b.fill_spans(|out| {
            out.push(Span::new(0, 2));
            out.push(Span::new(3, 5));
        });
        assert_eq!(b.len(), 2);
        assert_eq!(b.spans(0), &[Span::new(0, 2), Span::new(3, 5)]);
    }

    #[test]
    fn union_extend_preserves_nulls() {
        let s = Schema::of(&[("n", FieldType::Int)]);
        let a = TupleBatch::from_rows(&s, &[vec![Value::Int(1)], vec![Value::Null]]);
        let b = TupleBatch::from_rows(&s, &[vec![Value::Null], vec![Value::Int(4)]]);
        let mut u = TupleBatch::like(&a);
        u.extend_from(&a);
        u.extend_from(&b);
        assert_eq!(
            u.to_tuples(),
            vec![
                vec![Value::Int(1)],
                vec![Value::Null],
                vec![Value::Null],
                vec![Value::Int(4)]
            ]
        );
    }

    #[test]
    fn cell_compare_and_equality() {
        let s = Schema::of(&[("n", FieldType::Int)]);
        let b = TupleBatch::from_rows(
            &s,
            &[vec![Value::Int(2)], vec![Value::Int(1)], vec![Value::Null]],
        );
        let c = b.column(0);
        assert_eq!(c.cmp_cells(1, c, 0), Ordering::Less);
        assert_eq!(c.cmp_cells(0, c, 0), Ordering::Equal);
        // nulls sort last, equal to each other
        assert_eq!(c.cmp_cells(2, c, 0), Ordering::Greater);
        assert_eq!(c.cmp_cells(2, c, 2), Ordering::Equal);
        assert!(c.eq_cells(2, c, 2));
        assert!(!c.eq_cells(2, c, 0));
        assert!(TupleBatch::rows_equal(&b, 0, &b, 0));
        assert!(!TupleBatch::rows_equal(&b, 0, &b, 1));
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn type_mismatch_panics() {
        let mut b = TupleBatch::for_schema(&Schema::of(&[("n", FieldType::Int)]));
        b.push_row([Value::Bool(true)]);
    }

    #[test]
    fn arena_recycles_buffers() {
        // warm up: create and drop a batch, then confirm that rebuilding
        // the same shape does not take fresh allocations from the arena
        let s = schema();
        let rows: Vec<Tuple> = vec![vec![
            Value::Span(Span::new(0, 1)),
            Value::Int(1),
            Value::Str("x".into()),
        ]];
        drop(TupleBatch::from_rows(&s, &rows));
        let before = arena_stats();
        for _ in 0..10 {
            drop(TupleBatch::from_rows(&s, &rows));
        }
        let after = arena_stats();
        assert_eq!(
            after.fresh, before.fresh,
            "steady-state rebuilds must be served from the pool"
        );
        assert!(after.checkouts > before.checkouts);
        assert!(after.pooled >= 3);
    }

    #[test]
    fn block_pool_rezeroes_recycled_blocks() {
        let mut b = take_block(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 0));
        b[0] = 77;
        b[15] = -1;
        recycle_block(b);
        // same-thread retake must come from the local cache, re-zeroed,
        // even when the requested length grows (adaptive block sizes)
        let b2 = take_block(32);
        assert_eq!(b2.len(), 32);
        assert!(
            b2.iter().all(|&x| x == 0),
            "recycled blocks must be re-zeroed (NUL separators rely on it)"
        );
        recycle_block(b2);
        let s = block_pool_stats();
        assert!(s.checkouts >= 2);
        assert!(s.returns >= 2);
    }

    #[test]
    fn clone_is_arena_backed_and_deep() {
        let s = Schema::of(&[("m", FieldType::Span)]);
        let a = TupleBatch::from_rows(&s, &[vec![Value::Span(Span::new(2, 4))]]);
        let b = a.clone();
        drop(a);
        assert_eq!(b.to_tuples(), vec![vec![Value::Span(Span::new(2, 4))]]);
    }

    #[test]
    fn arena_id_mapping() {
        // worker ids wrap over the worker shards and never land on a
        // reserved communication shard
        for w in 0..3 * NUM_SHARDS {
            let id = ArenaId::for_worker(w);
            assert!(
                id.shard() < WORKER_SHARDS,
                "worker {w} on shard {}",
                id.shard()
            );
            for d in 0..MAX_COMM_SHARDS {
                assert_ne!(id, ArenaId::comm_for(d));
            }
        }
        assert_eq!(ArenaId::for_worker(0), ArenaId::for_worker(WORKER_SHARDS));
        // device 0 keeps the historical single-device comm shard, and the
        // pool shards are distinct until they wrap at MAX_COMM_SHARDS
        assert_eq!(ArenaId::comm(), ArenaId::comm_for(0));
        assert_eq!(ArenaId::comm().shard(), NUM_SHARDS - 1);
        for d in 1..MAX_COMM_SHARDS {
            assert_ne!(ArenaId::comm_for(d), ArenaId::comm_for(d - 1));
            assert!(ArenaId::comm_for(d).shard() >= WORKER_SHARDS);
        }
        assert_eq!(ArenaId::comm_for(MAX_COMM_SHARDS), ArenaId::comm_for(0));
        assert_eq!(shard_stats().len(), NUM_SHARDS);
    }

    #[test]
    fn same_thread_drop_counts_local_return() {
        // libtest gives every #[test] its own thread, so pinning here
        // cannot leak into other tests
        pin_thread(ArenaId::for_worker(9));
        let home = ArenaId::for_worker(9).shard();
        let before = shard_stats()[home];
        drop(TupleBatch::from_rows(
            &Schema::of(&[("m", FieldType::Span)]),
            &[vec![Value::Span(Span::new(0, 1))]],
        ));
        let after = shard_stats()[home];
        assert!(after.checkouts > before.checkouts);
        assert!(
            after.returns_local > before.returns_local,
            "a home-origin buffer dropped on its own thread is a local return"
        );
    }

    #[test]
    fn cross_thread_drop_routes_buffers_back_to_origin_shard() {
        pin_thread(ArenaId::for_worker(12));
        let origin = ArenaId::for_worker(12).shard();
        let b = TupleBatch::from_rows(
            &Schema::of(&[("m", FieldType::Span), ("n", FieldType::Int)]),
            &[vec![Value::Span(Span::new(2, 4)), Value::Int(7)]],
        );
        let before = shard_stats()[origin];
        std::thread::spawn(move || {
            // a differently-homed thread (the communication shard) drops
            // the batch: every buffer must be routed home, not absorbed
            // into this thread's pools
            pin_thread(ArenaId::comm());
            drop(b);
        })
        .join()
        .unwrap();
        let after = shard_stats()[origin];
        assert!(
            after.returns_cross >= before.returns_cross + 2,
            "both column buffers must come home as cross-thread returns \
             (before {}, after {})",
            before.returns_cross,
            after.returns_cross
        );
    }

    #[test]
    fn global_stats_aggregate_shards() {
        drop(TupleBatch::single_span());
        // aggregate the SAME snapshot (concurrent tests keep ticking the
        // live counters, so two reads are not comparable)
        let shards = shard_stats();
        let total = crate::metrics::ArenaSnapshot::from_shards(&shards);
        assert_eq!(
            total.checkouts,
            shards.iter().map(|s| s.checkouts).sum::<u64>()
        );
        assert!(total.checkouts > 0);
        assert!(global_arena_stats().checkouts >= total.checkouts);
    }

    #[test]
    fn spans_slice_panics_on_null() {
        let s = Schema::of(&[("m", FieldType::Span)]);
        let b = TupleBatch::from_rows(&s, &[vec![Value::Null]]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.spans(0);
        }));
        assert!(r.is_err());
    }
}
