//! Columnar tuple batches and the per-thread buffer arena — the storage
//! layer of the software executor's hot path.
//!
//! The seed executor materialized every operator output as `Vec<Tuple>`
//! with `Tuple = Vec<Value>`: one heap allocation per tuple per operator
//! per document, through a 16-byte-tagged enum even when a column is pure
//! spans. The paper's software baseline is supposed to be memory-bandwidth
//! bound, not allocator bound, so this module replaces that layout with:
//!
//! * [`TupleBatch`] — one buffer per *column*, typed ([`ColumnData`]:
//!   spans, ints, floats, bools, strings) plus a lazily-materialized null
//!   bitmap ([`NullMask`], absent in the common all-valid case). A batch
//!   of `n` span tuples is a single `Vec<Span>` instead of `n` boxed rows.
//! * [`BatchArena`] — a per-thread pool of recycled column buffers.
//!   Buffers are checked out when an operator builds its output batch and
//!   returned (cleared, **not** freed) when the batch drops, so a worker
//!   thread reaches a steady state of near-zero allocations per document.
//! * [`TupleRef`] — a cursor over one row of a batch, implementing
//!   [`RowAccess`] so the scalar expression evaluator runs unchanged over
//!   both layouts; [`JoinRow`] concatenates two cursors for join
//!   predicates without materializing the combined row.
//!
//! Row-oriented `Tuple`s survive only at the API boundary:
//! [`DocResult`](super::DocResult) converts lazily on first access.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::sync::Arc;

use crate::aog::expr::RowAccess;
use crate::aog::{FieldType, Schema, Tuple, Value};
use crate::text::Span;

/// Typed storage for one column of a [`TupleBatch`].
#[derive(Debug, Clone)]
pub enum ColumnData {
    Spans(Vec<Span>),
    Ints(Vec<i64>),
    Floats(Vec<f64>),
    Bools(Vec<bool>),
    Strs(Vec<Arc<str>>),
}

impl ColumnData {
    /// Number of cells.
    pub fn len(&self) -> usize {
        match self {
            ColumnData::Spans(v) => v.len(),
            ColumnData::Ints(v) => v.len(),
            ColumnData::Floats(v) => v.len(),
            ColumnData::Bools(v) => v.len(),
            ColumnData::Strs(v) => v.len(),
        }
    }

    /// True when the column holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The column's declared type.
    pub fn field_type(&self) -> FieldType {
        match self {
            ColumnData::Spans(_) => FieldType::Span,
            ColumnData::Ints(_) => FieldType::Int,
            ColumnData::Floats(_) => FieldType::Float,
            ColumnData::Bools(_) => FieldType::Bool,
            ColumnData::Strs(_) => FieldType::Str,
        }
    }

    fn clear(&mut self) {
        match self {
            ColumnData::Spans(v) => v.clear(),
            ColumnData::Ints(v) => v.clear(),
            ColumnData::Floats(v) => v.clear(),
            ColumnData::Bools(v) => v.clear(),
            ColumnData::Strs(v) => v.clear(),
        }
    }

}

/// The shared empty-string placeholder null cells use — a refcount bump
/// instead of a per-null allocation.
fn empty_str() -> Arc<str> {
    use std::sync::OnceLock;
    static EMPTY: OnceLock<Arc<str>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from("")).clone()
}

/// Per-row null flags, packed 64 rows per word. Only allocated once a null
/// actually appears — extraction and the span algebra never produce one.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NullMask {
    words: Vec<u64>,
}

impl NullMask {
    fn set(&mut self, i: usize) {
        let w = i / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    fn get(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .is_some_and(|w| (w >> (i % 64)) & 1 == 1)
    }

    fn any(&self) -> bool {
        self.words.iter().any(|w| *w != 0)
    }
}

/// One typed column plus its (usually absent) null bitmap. Data buffers
/// come from the per-thread [`BatchArena`] and return to it on drop.
#[derive(Debug)]
pub struct Column {
    data: ColumnData,
    nulls: Option<NullMask>,
}

impl Column {
    /// Checked-out empty column of type `ty`.
    fn new(ty: FieldType) -> Column {
        Column {
            data: arena_take(ty),
            nulls: None,
        }
    }

    /// Number of cells.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the column holds no cells.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The column's declared type.
    pub fn field_type(&self) -> FieldType {
        self.data.field_type()
    }

    /// The typed storage.
    pub fn data(&self) -> &ColumnData {
        &self.data
    }

    /// True when cell `i` is null.
    #[inline]
    pub fn is_null(&self, i: usize) -> bool {
        self.nulls.as_ref().is_some_and(|m| m.get(i))
    }

    fn push_null(&mut self) {
        // a placeholder keeps the typed buffer dense; the mask records it
        match &mut self.data {
            ColumnData::Spans(v) => v.push(Span::new(0, 0)),
            ColumnData::Ints(v) => v.push(0),
            ColumnData::Floats(v) => v.push(0.0),
            ColumnData::Bools(v) => v.push(false),
            ColumnData::Strs(v) => v.push(empty_str()),
        }
        let i = self.data.len() - 1;
        self.nulls.get_or_insert_with(NullMask::default).set(i);
    }

    /// Append `v`; its kind must match the column type (or be null).
    pub fn push_value(&mut self, v: &Value) {
        if matches!(v, Value::Null) {
            self.push_null();
            return;
        }
        match (&mut self.data, v) {
            (ColumnData::Spans(d), Value::Span(s)) => d.push(*s),
            (ColumnData::Ints(d), Value::Int(x)) => d.push(*x),
            (ColumnData::Floats(d), Value::Float(x)) => d.push(*x),
            (ColumnData::Bools(d), Value::Bool(x)) => d.push(*x),
            (ColumnData::Strs(d), Value::Str(s)) => d.push(s.clone()),
            (d, v) => panic!("value {v:?} does not fit a {} column", d.field_type()),
        }
    }

    /// Append cell `i` of `src` (same column type) without going through
    /// `Value` — the row-copy primitive of select/consolidate/sort/limit.
    #[inline]
    pub fn push_cell(&mut self, src: &Column, i: usize) {
        if src.is_null(i) {
            self.push_null();
            return;
        }
        match (&mut self.data, &src.data) {
            (ColumnData::Spans(d), ColumnData::Spans(s)) => d.push(s[i]),
            (ColumnData::Ints(d), ColumnData::Ints(s)) => d.push(s[i]),
            (ColumnData::Floats(d), ColumnData::Floats(s)) => d.push(s[i]),
            (ColumnData::Bools(d), ColumnData::Bools(s)) => d.push(s[i]),
            (ColumnData::Strs(d), ColumnData::Strs(s)) => d.push(s[i].clone()),
            (d, s) => panic!(
                "column type mismatch: {} cell into {} column",
                s.field_type(),
                d.field_type()
            ),
        }
    }

    /// Append every cell of `src` (same column type) — the union primitive.
    pub fn extend_from(&mut self, src: &Column) {
        let base = self.data.len();
        match (&mut self.data, &src.data) {
            (ColumnData::Spans(d), ColumnData::Spans(s)) => d.extend_from_slice(s),
            (ColumnData::Ints(d), ColumnData::Ints(s)) => d.extend_from_slice(s),
            (ColumnData::Floats(d), ColumnData::Floats(s)) => d.extend_from_slice(s),
            (ColumnData::Bools(d), ColumnData::Bools(s)) => d.extend_from_slice(s),
            (ColumnData::Strs(d), ColumnData::Strs(s)) => d.extend_from_slice(s),
            (d, s) => panic!(
                "column type mismatch: extending {} column with {}",
                d.field_type(),
                s.field_type()
            ),
        }
        if let Some(src_nulls) = &src.nulls {
            if src_nulls.any() {
                let dst = self.nulls.get_or_insert_with(NullMask::default);
                for i in 0..src.data.len() {
                    if src_nulls.get(i) {
                        dst.set(base + i);
                    }
                }
            }
        }
    }

    /// Cell `i` as an owned [`Value`] (the API-boundary conversion).
    pub fn value(&self, i: usize) -> Value {
        if self.is_null(i) {
            return Value::Null;
        }
        match &self.data {
            ColumnData::Spans(v) => Value::Span(v[i]),
            ColumnData::Ints(v) => Value::Int(v[i]),
            ColumnData::Floats(v) => Value::Float(v[i]),
            ColumnData::Bools(v) => Value::Bool(v[i]),
            ColumnData::Strs(v) => Value::Str(v[i].clone()),
        }
    }

    /// Cell `i` as a span (panics on nulls or non-span columns, mirroring
    /// [`Value::as_span`]).
    #[inline]
    pub fn span(&self, i: usize) -> Span {
        match &self.data {
            ColumnData::Spans(v) if !self.is_null(i) => v[i],
            _ => panic!("expected span, got {:?}", self.value(i)),
        }
    }

    /// Total order over two cells, mirroring
    /// [`cmp_values`](super::operators::cmp_values): same-type natural
    /// order, nulls last, float ties resolved as equal.
    pub fn cmp_cells(&self, i: usize, other: &Column, j: usize) -> Ordering {
        match (self.is_null(i), other.is_null(j)) {
            (true, true) => return Ordering::Equal,
            (true, false) => return Ordering::Greater,
            (false, true) => return Ordering::Less,
            (false, false) => {}
        }
        match (&self.data, &other.data) {
            (ColumnData::Spans(a), ColumnData::Spans(b)) => a[i].cmp(&b[j]),
            (ColumnData::Ints(a), ColumnData::Ints(b)) => a[i].cmp(&b[j]),
            (ColumnData::Floats(a), ColumnData::Floats(b)) => {
                a[i].partial_cmp(&b[j]).unwrap_or(Ordering::Equal)
            }
            (ColumnData::Bools(a), ColumnData::Bools(b)) => a[i].cmp(&b[j]),
            (ColumnData::Strs(a), ColumnData::Strs(b)) => a[i].cmp(&b[j]),
            _ => Ordering::Equal, // mixed types cannot occur in a typed column
        }
    }

    /// Cell equality with [`Value`]'s `PartialEq` semantics (`NaN != NaN`,
    /// `Null == Null`) — the difference operator's set membership.
    pub fn eq_cells(&self, i: usize, other: &Column, j: usize) -> bool {
        match (self.is_null(i), other.is_null(j)) {
            (true, true) => return true,
            (true, false) | (false, true) => return false,
            (false, false) => {}
        }
        match (&self.data, &other.data) {
            (ColumnData::Spans(a), ColumnData::Spans(b)) => a[i] == b[j],
            (ColumnData::Ints(a), ColumnData::Ints(b)) => a[i] == b[j],
            (ColumnData::Floats(a), ColumnData::Floats(b)) => a[i] == b[j],
            (ColumnData::Bools(a), ColumnData::Bools(b)) => a[i] == b[j],
            (ColumnData::Strs(a), ColumnData::Strs(b)) => a[i] == b[j],
            _ => false,
        }
    }
}

impl Clone for Column {
    fn clone(&self) -> Column {
        // clones are arena-backed too, so results escaping into DocResults
        // keep recycling wherever they are eventually dropped
        let mut c = Column::new(self.data.field_type());
        c.extend_from(self);
        c
    }
}

impl Drop for Column {
    fn drop(&mut self) {
        let data = std::mem::replace(&mut self.data, ColumnData::Bools(Vec::new()));
        arena_recycle(data);
    }
}

/// A columnar batch of tuples: one [`Column`] per schema field, all the
/// same length. The executor's operators consume and produce these; rows
/// exist only as [`TupleRef`] cursors until the API boundary converts.
#[derive(Debug)]
pub struct TupleBatch {
    columns: Vec<Column>,
    len: usize,
}

impl TupleBatch {
    /// Empty batch with one checked-out column per field of `schema`.
    pub fn for_schema(schema: &Schema) -> TupleBatch {
        let mut columns = arena_take_columns();
        columns.extend(schema.fields.iter().map(|f| Column::new(f.ty)));
        TupleBatch { columns, len: 0 }
    }

    /// Empty batch with the same column layout as `src`.
    pub fn like(src: &TupleBatch) -> TupleBatch {
        let mut columns = arena_take_columns();
        columns.extend(src.columns.iter().map(|c| Column::new(c.field_type())));
        TupleBatch { columns, len: 0 }
    }

    /// Empty batch whose layout is `left`'s columns followed by `right`'s
    /// — the join output shape.
    pub fn concat_layout(left: &TupleBatch, right: &TupleBatch) -> TupleBatch {
        let mut columns = arena_take_columns();
        columns.extend(
            left.columns
                .iter()
                .chain(&right.columns)
                .map(|c| Column::new(c.field_type())),
        );
        TupleBatch { columns, len: 0 }
    }

    /// Empty single-span-column batch — the shape of every extraction
    /// leaf, `DocScan` and `Block`.
    pub fn single_span() -> TupleBatch {
        let mut columns = arena_take_columns();
        columns.push(Column::new(FieldType::Span));
        TupleBatch { columns, len: 0 }
    }

    /// Zero-column, zero-row batch.
    pub fn empty() -> TupleBatch {
        TupleBatch {
            columns: arena_take_columns(),
            len: 0,
        }
    }

    /// Convert a row-oriented view (the legacy layout) into a batch.
    pub fn from_rows(schema: &Schema, rows: &[Tuple]) -> TupleBatch {
        let mut b = TupleBatch::for_schema(schema);
        for t in rows {
            b.push_tuple(t);
        }
        b
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the batch holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of columns.
    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    /// Column `i`.
    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    /// The span cells of column `col` as a plain slice — the fast path for
    /// band joins, consolidate and block, which read a whole span column.
    /// Panics if the column is not spans or contains a null (mirroring the
    /// per-row [`Value::as_span`] contract).
    pub fn spans(&self, col: usize) -> &[Span] {
        let c = &self.columns[col];
        assert!(
            !c.nulls.as_ref().is_some_and(|m| m.any()),
            "expected span, got null"
        );
        match &c.data {
            ColumnData::Spans(v) => v,
            other => panic!("expected span column, got {}", other.field_type()),
        }
    }

    /// Span cell at (`row`, `col`).
    #[inline]
    pub fn span_at(&self, row: usize, col: usize) -> Span {
        self.columns[col].span(row)
    }

    /// Owned [`Value`] at (`row`, `col`).
    pub fn value(&self, row: usize, col: usize) -> Value {
        self.columns[col].value(row)
    }

    /// Cursor over row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> TupleRef<'_> {
        debug_assert!(i < self.len);
        TupleRef { batch: self, row: i }
    }

    /// Iterate all rows as cursors.
    pub fn rows(&self) -> impl Iterator<Item = TupleRef<'_>> {
        (0..self.len).map(move |i| self.row(i))
    }

    /// Append one row of owned values (must match the column count).
    pub fn push_row<I: IntoIterator<Item = Value>>(&mut self, vals: I) {
        let mut n = 0;
        for (i, v) in vals.into_iter().enumerate() {
            self.columns[i].push_value(&v);
            n += 1;
        }
        debug_assert_eq!(n, self.columns.len(), "row arity mismatch");
        self.len += 1;
    }

    /// Append one legacy row.
    pub fn push_tuple(&mut self, t: &Tuple) {
        debug_assert_eq!(t.len(), self.columns.len(), "row arity mismatch");
        for (c, v) in self.columns.iter_mut().zip(t) {
            c.push_value(v);
        }
        self.len += 1;
    }

    /// Append row `row` of `src` (same layout).
    #[inline]
    pub fn push_row_from(&mut self, src: &TupleBatch, row: usize) {
        for (dst, s) in self.columns.iter_mut().zip(&src.columns) {
            dst.push_cell(s, row);
        }
        self.len += 1;
    }

    /// Append the concatenation of `left[li]` and `right[ri]` (layout from
    /// [`TupleBatch::concat_layout`]) — the join emit primitive.
    #[inline]
    pub fn push_joined_row(
        &mut self,
        left: &TupleBatch,
        li: usize,
        right: &TupleBatch,
        ri: usize,
    ) {
        let la = left.columns.len();
        for (k, dst) in self.columns.iter_mut().enumerate() {
            if k < la {
                dst.push_cell(&left.columns[k], li);
            } else {
                dst.push_cell(&right.columns[k - la], ri);
            }
        }
        self.len += 1;
    }

    /// Append a one-span row (single-span-column batches only).
    #[inline]
    pub fn push_span(&mut self, s: Span) {
        debug_assert_eq!(self.columns.len(), 1);
        match &mut self.columns[0].data {
            ColumnData::Spans(v) => v.push(s),
            other => panic!("push_span on a {} column", other.field_type()),
        }
        self.len += 1;
    }

    /// Append every row of `other` (same layout) — the union primitive.
    pub fn extend_from(&mut self, other: &TupleBatch) {
        debug_assert_eq!(self.columns.len(), other.columns.len());
        for (dst, s) in self.columns.iter_mut().zip(&other.columns) {
            dst.extend_from(s);
        }
        self.len += other.len;
    }

    /// Hand the single span column's buffer to `f` for direct filling —
    /// how extraction leaves (and the accelerator's span reconstruction)
    /// emit matches straight into arena-backed column storage with no
    /// intermediate per-match values. The batch must be empty; its length
    /// becomes whatever `f` pushed.
    pub fn fill_spans<F: FnOnce(&mut Vec<Span>)>(&mut self, f: F) {
        assert_eq!(self.len, 0, "fill_spans on a non-empty batch");
        assert_eq!(self.columns.len(), 1, "fill_spans needs a single column");
        match &mut self.columns[0].data {
            ColumnData::Spans(v) => {
                f(v);
                self.len = v.len();
            }
            other => panic!("fill_spans on a {} column", other.field_type()),
        }
    }

    /// Row equality across batches of the same layout (the `Difference`
    /// operator's membership test), with [`Value`] `PartialEq` semantics.
    pub fn rows_equal(a: &TupleBatch, ai: usize, b: &TupleBatch, bi: usize) -> bool {
        debug_assert_eq!(a.columns.len(), b.columns.len());
        a.columns
            .iter()
            .zip(&b.columns)
            .all(|(ca, cb)| ca.eq_cells(ai, cb, bi))
    }

    /// Materialize the legacy row layout (API boundary only).
    pub fn to_tuples(&self) -> Vec<Tuple> {
        (0..self.len)
            .map(|i| self.columns.iter().map(|c| c.value(i)).collect())
            .collect()
    }
}

impl Clone for TupleBatch {
    fn clone(&self) -> TupleBatch {
        let mut columns = arena_take_columns();
        columns.extend(self.columns.iter().cloned());
        TupleBatch {
            columns,
            len: self.len,
        }
    }
}

impl Drop for TupleBatch {
    fn drop(&mut self) {
        // drop the columns first (each recycles its data buffer), then
        // pool the emptied container itself
        self.columns.clear();
        arena_recycle_columns(std::mem::take(&mut self.columns));
    }
}

/// A cursor over one row of a [`TupleBatch`]. Implements [`RowAccess`], so
/// predicates and projections evaluate against it directly.
#[derive(Clone, Copy)]
pub struct TupleRef<'a> {
    batch: &'a TupleBatch,
    row: usize,
}

impl TupleRef<'_> {
    /// Number of columns.
    pub fn arity(&self) -> usize {
        self.batch.columns.len()
    }

    /// Owned value of column `col`.
    #[inline]
    pub fn value(&self, col: usize) -> Value {
        self.batch.columns[col].value(self.row)
    }

    /// Span of column `col` (panics on non-span/null).
    #[inline]
    pub fn span(&self, col: usize) -> Span {
        self.batch.columns[col].span(self.row)
    }

    /// Materialize the row as a legacy [`Tuple`].
    pub fn to_tuple(&self) -> Tuple {
        (0..self.arity()).map(|c| self.value(c)).collect()
    }
}

impl RowAccess for TupleRef<'_> {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        self.value(i)
    }
}

/// Two row cursors seen as one concatenated row — how join predicates
/// evaluate over a candidate pair without building the combined tuple.
#[derive(Clone, Copy)]
pub struct JoinRow<'a> {
    pub left: TupleRef<'a>,
    pub right: TupleRef<'a>,
}

impl RowAccess for JoinRow<'_> {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        let la = self.left.arity();
        if i < la {
            self.left.value(i)
        } else {
            self.right.value(i - la)
        }
    }
}

// ---------------------------------------------------------------------------
// The per-thread arena.

/// Upper bound of pooled buffers per type per thread: enough to cover every
/// live node slot of a large merged catalog, small enough that an idle
/// worker pins only a bounded amount of memory.
const MAX_POOLED: usize = 256;

/// Pools of recycled column buffers, one instance per thread. Checked out
/// by [`TupleBatch`] constructors, refilled by `Column`/`TupleBatch` drops;
/// a buffer is cleared on return (len 0, capacity kept), so steady-state
/// execution re-uses warm capacity instead of round-tripping the global
/// allocator.
///
/// Known limitation: recycling is strictly per-thread, so batches that
/// migrate threads (accelerator submissions built on a worker but dropped
/// on the communication thread, and vice versa) refill the *receiving*
/// thread's pool — the near-zero-alloc steady state is guaranteed only
/// for the software path, where a document's batches live and die on one
/// worker. Pools are capped ([`MAX_POOLED`] per type), so migration never
/// grows memory unboundedly; making the accelerated path allocation-free
/// would need a return-to-origin or global pool (ROADMAP open item).
#[derive(Debug, Default)]
pub struct BatchArena {
    spans: Vec<Vec<Span>>,
    ints: Vec<Vec<i64>>,
    floats: Vec<Vec<f64>>,
    bools: Vec<Vec<bool>>,
    strs: Vec<Vec<Arc<str>>>,
    columns: Vec<Vec<Column>>,
    checkouts: u64,
    fresh: u64,
}

impl BatchArena {
    fn take(&mut self, ty: FieldType) -> ColumnData {
        self.checkouts += 1;
        macro_rules! pool {
            ($pool:expr, $variant:path) => {
                match $pool.pop() {
                    Some(v) => $variant(v),
                    None => {
                        self.fresh += 1;
                        $variant(Vec::new())
                    }
                }
            };
        }
        match ty {
            FieldType::Span => pool!(self.spans, ColumnData::Spans),
            FieldType::Int => pool!(self.ints, ColumnData::Ints),
            FieldType::Float => pool!(self.floats, ColumnData::Floats),
            FieldType::Bool => pool!(self.bools, ColumnData::Bools),
            FieldType::Str => pool!(self.strs, ColumnData::Strs),
        }
    }

    fn put(&mut self, mut data: ColumnData) {
        // pool even zero-capacity buffers: a column that stays empty all
        // run still checks a buffer out per document, and a pool miss
        // counts as `fresh` — supply must match demand or the
        // steady-state invariant (fresh stops growing after warm-up)
        // would fail on never-matching columns.
        // clear before pooling: for string columns this releases the Arc
        // references immediately instead of pinning document text
        data.clear();
        match data {
            ColumnData::Spans(v) if self.spans.len() < MAX_POOLED => self.spans.push(v),
            ColumnData::Ints(v) if self.ints.len() < MAX_POOLED => self.ints.push(v),
            ColumnData::Floats(v) if self.floats.len() < MAX_POOLED => self.floats.push(v),
            ColumnData::Bools(v) if self.bools.len() < MAX_POOLED => self.bools.push(v),
            ColumnData::Strs(v) if self.strs.len() < MAX_POOLED => self.strs.push(v),
            _ => {} // pool full: let the buffer free
        }
    }

    fn take_columns(&mut self) -> Vec<Column> {
        self.columns.pop().unwrap_or_default()
    }

    fn put_columns(&mut self, v: Vec<Column>) {
        debug_assert!(v.is_empty());
        if v.capacity() > 0 && self.columns.len() < MAX_POOLED {
            self.columns.push(v);
        }
    }

    fn stats(&self) -> ArenaStats {
        ArenaStats {
            checkouts: self.checkouts,
            fresh: self.fresh,
            pooled: self.spans.len()
                + self.ints.len()
                + self.floats.len()
                + self.bools.len()
                + self.strs.len(),
        }
    }
}

/// Gauges of the calling thread's arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffer checkouts since the thread started.
    pub checkouts: u64,
    /// Checkouts that had to allocate a fresh buffer (pool miss). After
    /// warm-up this stops growing — the recycling invariant the
    /// `bench-alloc` tests pin.
    pub fresh: u64,
    /// Buffers currently parked in the pools.
    pub pooled: usize,
}

thread_local! {
    static ARENA: RefCell<BatchArena> = RefCell::new(BatchArena::default());
}

fn arena_take(ty: FieldType) -> ColumnData {
    ARENA
        .try_with(|a| a.borrow_mut().take(ty))
        .unwrap_or_else(|_| match ty {
            // thread teardown: the arena is gone, allocate plainly
            FieldType::Span => ColumnData::Spans(Vec::new()),
            FieldType::Int => ColumnData::Ints(Vec::new()),
            FieldType::Float => ColumnData::Floats(Vec::new()),
            FieldType::Bool => ColumnData::Bools(Vec::new()),
            FieldType::Str => ColumnData::Strs(Vec::new()),
        })
}

fn arena_recycle(data: ColumnData) {
    let _ = ARENA.try_with(|a| a.borrow_mut().put(data));
}

fn arena_take_columns() -> Vec<Column> {
    ARENA
        .try_with(|a| a.borrow_mut().take_columns())
        .unwrap_or_default()
}

fn arena_recycle_columns(v: Vec<Column>) {
    let _ = ARENA.try_with(|a| a.borrow_mut().put_columns(v));
}

/// Snapshot the calling thread's arena gauges.
pub fn arena_stats() -> ArenaStats {
    ARENA
        .try_with(|a| a.borrow().stats())
        .unwrap_or(ArenaStats {
            checkouts: 0,
            fresh: 0,
            pooled: 0,
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::FieldType;

    fn schema() -> Schema {
        Schema::of(&[
            ("m", FieldType::Span),
            ("n", FieldType::Int),
            ("s", FieldType::Str),
        ])
    }

    #[test]
    fn roundtrip_rows_to_batch_and_back() {
        let rows: Vec<Tuple> = vec![
            vec![
                Value::Span(Span::new(0, 3)),
                Value::Int(7),
                Value::Str("a".into()),
            ],
            vec![Value::Span(Span::new(4, 6)), Value::Null, Value::Str("b".into())],
        ];
        let b = TupleBatch::from_rows(&schema(), &rows);
        assert_eq!(b.len(), 2);
        assert_eq!(b.num_columns(), 3);
        assert_eq!(b.to_tuples(), rows);
        assert!(b.column(1).is_null(1));
        assert!(!b.column(1).is_null(0));
        assert_eq!(b.value(0, 1), Value::Int(7));
        assert_eq!(b.value(1, 1), Value::Null);
    }

    #[test]
    fn row_cursor_and_join_row() {
        let rows: Vec<Tuple> = vec![vec![Value::Span(Span::new(1, 2)), Value::Int(5), Value::Str("x".into())]];
        let b = TupleBatch::from_rows(&schema(), &rows);
        let r = b.row(0);
        assert_eq!(r.arity(), 3);
        assert_eq!(r.span(0), Span::new(1, 2));
        assert_eq!(r.value_at(1), Value::Int(5));
        assert_eq!(r.to_tuple(), rows[0]);

        let j = JoinRow { left: b.row(0), right: b.row(0) };
        assert_eq!(j.value_at(0), Value::Span(Span::new(1, 2)));
        assert_eq!(j.value_at(4), Value::Int(5));
    }

    #[test]
    fn push_joined_row_concatenates() {
        let left = TupleBatch::from_rows(
            &Schema::of(&[("a", FieldType::Span)]),
            &[vec![Value::Span(Span::new(0, 1))]],
        );
        let right = TupleBatch::from_rows(
            &Schema::of(&[("b", FieldType::Int)]),
            &[vec![Value::Int(9)]],
        );
        let mut out = TupleBatch::concat_layout(&left, &right);
        out.push_joined_row(&left, 0, &right, 0);
        assert_eq!(
            out.to_tuples(),
            vec![vec![Value::Span(Span::new(0, 1)), Value::Int(9)]]
        );
    }

    #[test]
    fn fill_spans_direct_emit() {
        let mut b = TupleBatch::single_span();
        b.fill_spans(|out| {
            out.push(Span::new(0, 2));
            out.push(Span::new(3, 5));
        });
        assert_eq!(b.len(), 2);
        assert_eq!(b.spans(0), &[Span::new(0, 2), Span::new(3, 5)]);
    }

    #[test]
    fn union_extend_preserves_nulls() {
        let s = Schema::of(&[("n", FieldType::Int)]);
        let a = TupleBatch::from_rows(&s, &[vec![Value::Int(1)], vec![Value::Null]]);
        let b = TupleBatch::from_rows(&s, &[vec![Value::Null], vec![Value::Int(4)]]);
        let mut u = TupleBatch::like(&a);
        u.extend_from(&a);
        u.extend_from(&b);
        assert_eq!(
            u.to_tuples(),
            vec![
                vec![Value::Int(1)],
                vec![Value::Null],
                vec![Value::Null],
                vec![Value::Int(4)]
            ]
        );
    }

    #[test]
    fn cell_compare_and_equality() {
        let s = Schema::of(&[("n", FieldType::Int)]);
        let b = TupleBatch::from_rows(
            &s,
            &[vec![Value::Int(2)], vec![Value::Int(1)], vec![Value::Null]],
        );
        let c = b.column(0);
        assert_eq!(c.cmp_cells(1, c, 0), Ordering::Less);
        assert_eq!(c.cmp_cells(0, c, 0), Ordering::Equal);
        // nulls sort last, equal to each other
        assert_eq!(c.cmp_cells(2, c, 0), Ordering::Greater);
        assert_eq!(c.cmp_cells(2, c, 2), Ordering::Equal);
        assert!(c.eq_cells(2, c, 2));
        assert!(!c.eq_cells(2, c, 0));
        assert!(TupleBatch::rows_equal(&b, 0, &b, 0));
        assert!(!TupleBatch::rows_equal(&b, 0, &b, 1));
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn type_mismatch_panics() {
        let mut b = TupleBatch::for_schema(&Schema::of(&[("n", FieldType::Int)]));
        b.push_row([Value::Bool(true)]);
    }

    #[test]
    fn arena_recycles_buffers() {
        // warm up: create and drop a batch, then confirm that rebuilding
        // the same shape does not take fresh allocations from the arena
        let s = schema();
        let rows: Vec<Tuple> = vec![vec![
            Value::Span(Span::new(0, 1)),
            Value::Int(1),
            Value::Str("x".into()),
        ]];
        drop(TupleBatch::from_rows(&s, &rows));
        let before = arena_stats();
        for _ in 0..10 {
            drop(TupleBatch::from_rows(&s, &rows));
        }
        let after = arena_stats();
        assert_eq!(
            after.fresh, before.fresh,
            "steady-state rebuilds must be served from the pool"
        );
        assert!(after.checkouts > before.checkouts);
        assert!(after.pooled >= 3);
    }

    #[test]
    fn clone_is_arena_backed_and_deep() {
        let s = Schema::of(&[("m", FieldType::Span)]);
        let a = TupleBatch::from_rows(&s, &[vec![Value::Span(Span::new(2, 4))]]);
        let b = a.clone();
        drop(a);
        assert_eq!(b.to_tuples(), vec![vec![Value::Span(Span::new(2, 4))]]);
    }

    #[test]
    fn spans_slice_panics_on_null() {
        let s = Schema::of(&[("m", FieldType::Span)]);
        let b = TupleBatch::from_rows(&s, &[vec![Value::Null]]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.spans(0);
        }));
        assert!(r.is_err());
    }
}
