//! Per-operator wall-time profiler — the instrument behind the paper's
//! Fig 4 ("relative time spent on executing different operators") and the
//! `rt_SW` term of the Eq. 1 throughput estimate.
//!
//! Worker threads accumulate per-node nanoseconds into atomics; a snapshot
//! groups them by operator family and computes the relative distribution.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::aog::Graph;

/// Thread-safe accumulating profiler. One instance per engine run; cheap
/// enough to leave on (two `Instant::now` calls per node per document).
pub struct Profiler {
    enabled: bool,
    node_ns: Vec<AtomicU64>,
}

impl Profiler {
    /// A disabled profiler: `start`/`stop` are no-ops.
    pub fn disabled() -> Profiler {
        Profiler {
            enabled: false,
            node_ns: Vec::new(),
        }
    }

    /// An enabled profiler pre-sized for `graph`.
    pub fn for_graph(graph: &Graph) -> Profiler {
        Profiler {
            enabled: true,
            node_ns: (0..graph.nodes.len()).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Begin timing (None when disabled).
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Finish timing node `id`.
    #[inline]
    pub fn stop(&self, id: usize, t0: Option<Instant>) {
        if let Some(t0) = t0 {
            let ns = t0.elapsed().as_nanos() as u64;
            if let Some(slot) = self.node_ns.get(id) {
                slot.fetch_add(ns, Ordering::Relaxed);
            }
        }
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for slot in &self.node_ns {
            slot.store(0, Ordering::Relaxed);
        }
    }

    /// Take a profile snapshot grouped over `graph`.
    pub fn snapshot(&self, graph: &Graph) -> Profile {
        let per_node: Vec<u64> = (0..graph.nodes.len())
            .map(|i| {
                self.node_ns
                    .get(i)
                    .map(|a| a.load(Ordering::Relaxed))
                    .unwrap_or(0)
            })
            .collect();
        let total: u64 = per_node.iter().sum();
        let mut by_op: BTreeMap<String, OpProfile> = BTreeMap::new();
        for node in &graph.nodes {
            let ns = per_node[node.id];
            let e = by_op.entry(node.kind.name().to_string()).or_default();
            e.ns += ns;
            e.nodes += 1;
        }
        for e in by_op.values_mut() {
            e.fraction = if total > 0 {
                e.ns as f64 / total as f64
            } else {
                0.0
            };
        }
        let extraction_ns: u64 = graph
            .nodes
            .iter()
            .filter(|n| n.kind.is_extraction())
            .map(|n| per_node[n.id])
            .sum();
        Profile {
            per_node,
            by_op,
            total_ns: total,
            extraction_ns,
        }
    }
}

/// Aggregate for one operator family.
#[derive(Debug, Clone, Default)]
pub struct OpProfile {
    /// Accumulated wall nanoseconds.
    pub ns: u64,
    /// Graph nodes in this family.
    pub nodes: usize,
    /// Share of total recorded time (0..=1).
    pub fraction: f64,
}

/// A profile snapshot.
#[derive(Debug, Clone)]
pub struct Profile {
    per_node: Vec<u64>,
    by_op: BTreeMap<String, OpProfile>,
    total_ns: u64,
    extraction_ns: u64,
}

impl Profile {
    /// Total accumulated nanoseconds.
    pub fn total_ns(&self) -> u64 {
        self.total_ns
    }

    /// Per-node nanoseconds (indexed by node id).
    pub fn per_node(&self) -> &[u64] {
        &self.per_node
    }

    /// Grouped by operator family name.
    pub fn by_operator(&self) -> &BTreeMap<String, OpProfile> {
        &self.by_op
    }

    /// Fraction of time in extraction operators (regex + dictionary) —
    /// the paper's "up to 82 %" observation, and the offloaded share in
    /// the Eq. 1 estimate's first scenario.
    pub fn fraction_extraction(&self) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            self.extraction_ns as f64 / self.total_ns as f64
        }
    }

    /// Fraction of time spent in a set of nodes (e.g. one subgraph).
    pub fn fraction_of_nodes(&self, nodes: &[usize]) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        let ns: u64 = nodes.iter().map(|&i| self.per_node[i]).sum();
        ns as f64 / self.total_ns as f64
    }

    /// Fig 4-style table: operator family → percent, sorted by the fixed
    /// bucket order used in the paper's figure.
    pub fn fig4_rows(&self) -> Vec<(String, f64)> {
        let order = [
            "RegularExpression",
            "Dictionary",
            "Join",
            "Select",
            "Consolidate",
            "Project",
            "Union",
            "Sort",
            "Limit",
            "DocScan",
            "SubgraphExec",
        ];
        let mut rows = Vec::new();
        for name in order {
            if let Some(p) = self.by_op.get(name) {
                if p.ns > 0 {
                    rows.push((name.to_string(), p.fraction * 100.0));
                }
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn disabled_profiler_is_noop() {
        let p = Profiler::disabled();
        assert!(p.start().is_none());
        p.stop(0, None);
    }

    #[test]
    fn snapshot_fractions_sum_to_one() {
        let g = crate::aql::compile(
            "create view A as extract regex /a+/ on d.text as m from Document d; \
             output view A;",
        )
        .unwrap();
        let prof = Profiler::for_graph(&g);
        // simulate recorded time
        prof.node_ns[0].store(100, Ordering::Relaxed);
        prof.node_ns[1].store(300, Ordering::Relaxed);
        let snap = prof.snapshot(&g);
        assert_eq!(snap.total_ns(), 400);
        let sum: f64 = snap.by_operator().values().map(|v| v.fraction).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((snap.fraction_extraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reset_zeroes() {
        let g = crate::aql::compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             output view A;",
        )
        .unwrap();
        let prof = Profiler::for_graph(&g);
        prof.node_ns[0].store(5, Ordering::Relaxed);
        prof.reset();
        assert_eq!(prof.snapshot(&g).total_ns(), 0);
    }

    #[test]
    fn concurrent_accumulation() {
        let g = crate::aql::compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             output view A;",
        )
        .unwrap();
        let prof = Arc::new(Profiler::for_graph(&g));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = prof.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    p.node_ns[1].fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(prof.snapshot(&g).per_node()[1], 8000);
    }

    #[test]
    fn fig4_rows_ordering() {
        let g = crate::aql::compile(
            "create dictionary D as ('x'); \
             create view A as extract dictionary 'D' on d.text as m from Document d; \
             create view B as extract regex /y/ on d.text as m from Document d; \
             output view A; output view B;",
        )
        .unwrap();
        let prof = Profiler::for_graph(&g);
        for (i, _) in g.nodes.iter().enumerate() {
            prof.node_ns[i].store(10, Ordering::Relaxed);
        }
        let rows = prof.snapshot(&g).fig4_rows();
        assert_eq!(rows[0].0, "RegularExpression");
        assert_eq!(rows[1].0, "Dictionary");
    }
}
