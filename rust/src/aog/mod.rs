//! The AOG — SystemT's operator graph, the IR of the whole system.
//!
//! An AQL query compiles into a DAG of operators ([`Graph`]): extraction
//! operators (regex, dictionary) at the leaves reading the document, and
//! relational operators (select, project, join, union, consolidate, sort,
//! limit) above them. The optimizer rewrites the graph, the partitioner
//! splits it into a software supergraph plus accelerator subgraphs (paper
//! Fig 1), and both the software executor and the hardware compiler consume
//! it.
//!
//! Tuples are rows of [`Value`]s described by a [`Schema`]; the span type
//! and its 32-bit offsets follow the paper (§3).

pub mod expr;
pub mod graph;
pub mod types;

pub use expr::{EvalCtx, Expr, Func};
pub use graph::{AggCol, Graph, GraphError, Node, NodeId, OpKind};
pub use types::{Field, FieldType, Schema, Tuple, Value};
