//! Tuple values and schemas.

use std::fmt;
use std::sync::Arc;

use crate::text::Span;

/// A runtime value. The type set mirrors the paper's §3: spans, integers,
/// floats, booleans — plus strings (for `GetText` results) and null.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A byte range into the document.
    Span(Span),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Interned string (e.g. `GetText` results).
    Str(Arc<str>),
    /// SQL-style null.
    Null,
}

impl Value {
    /// The value's type, or `None` for null.
    pub fn field_type(&self) -> Option<FieldType> {
        match self {
            Value::Span(_) => Some(FieldType::Span),
            Value::Int(_) => Some(FieldType::Int),
            Value::Float(_) => Some(FieldType::Float),
            Value::Bool(_) => Some(FieldType::Bool),
            Value::Str(_) => Some(FieldType::Str),
            Value::Null => None,
        }
    }

    /// Unwrap a span (panics on type mismatch — the compiler type-checks
    /// expressions before execution, so a mismatch is an engine bug).
    #[inline]
    pub fn as_span(&self) -> Span {
        match self {
            Value::Span(s) => *s,
            other => panic!("expected span, got {other:?}"),
        }
    }

    /// Unwrap an int.
    #[inline]
    pub fn as_int(&self) -> i64 {
        match self {
            Value::Int(i) => *i,
            other => panic!("expected int, got {other:?}"),
        }
    }

    /// Unwrap a bool.
    #[inline]
    pub fn as_bool(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            other => panic!("expected bool, got {other:?}"),
        }
    }

    /// Unwrap a string.
    #[inline]
    pub fn as_str(&self) -> &str {
        match self {
            Value::Str(s) => s,
            other => panic!("expected str, got {other:?}"),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Span(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Null => write!(f, "null"),
        }
    }
}

/// Column types.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FieldType {
    /// [`Value::Span`].
    Span,
    /// [`Value::Int`].
    Int,
    /// [`Value::Float`].
    Float,
    /// [`Value::Bool`].
    Bool,
    /// [`Value::Str`].
    Str,
}

impl fmt::Display for FieldType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FieldType::Span => "Span",
            FieldType::Int => "Integer",
            FieldType::Float => "Float",
            FieldType::Bool => "Boolean",
            FieldType::Str => "Text",
        };
        write!(f, "{s}")
    }
}

/// A named, typed column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    /// Column name.
    pub name: String,
    /// Column type.
    pub ty: FieldType,
}

/// An ordered list of fields. All operator input/output schemas are known
/// at compile time (paper §3) — the hardware compiler depends on this.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schema {
    /// The columns, in order.
    pub fields: Vec<Field>,
}

impl Schema {
    /// Build from `(name, type)` pairs.
    pub fn of(fields: &[(&str, FieldType)]) -> Schema {
        Schema {
            fields: fields
                .iter()
                .map(|(n, t)| Field {
                    name: n.to_string(),
                    ty: *t,
                })
                .collect(),
        }
    }

    /// Number of columns.
    pub fn arity(&self) -> usize {
        self.fields.len()
    }

    /// Index of column `name`.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|f| f.name == name)
    }

    /// Type of column `i`.
    pub fn type_at(&self, i: usize) -> FieldType {
        self.fields[i].ty
    }

    /// Concatenate (for joins): left columns then right columns; name
    /// collisions get the right side prefixed.
    pub fn concat(&self, right: &Schema) -> Schema {
        let mut fields = self.fields.clone();
        for f in &right.fields {
            let name = if self.index_of(&f.name).is_some() {
                format!("r_{}", f.name)
            } else {
                f.name.clone()
            };
            fields.push(Field {
                name,
                ty: f.ty,
            });
        }
        Schema { fields }
    }
}

impl fmt::Display for Schema {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, fld) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{} {}", fld.name, fld.ty)?;
        }
        write!(f, ")")
    }
}

/// A row. Plain vector — the executor's hot loops index positionally.
pub type Tuple = Vec<Value>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_types() {
        assert_eq!(Value::Int(3).field_type(), Some(FieldType::Int));
        assert_eq!(Value::Null.field_type(), None);
        assert_eq!(
            Value::Span(Span::new(0, 1)).field_type(),
            Some(FieldType::Span)
        );
    }

    #[test]
    fn unwraps() {
        assert_eq!(Value::Span(Span::new(1, 2)).as_span(), Span::new(1, 2));
        assert_eq!(Value::Int(-4).as_int(), -4);
        assert!(Value::Bool(true).as_bool());
        assert_eq!(Value::Str("x".into()).as_str(), "x");
    }

    #[test]
    #[should_panic(expected = "expected span")]
    fn wrong_unwrap_panics() {
        Value::Int(1).as_span();
    }

    #[test]
    fn schema_lookup_and_concat() {
        let a = Schema::of(&[("m", FieldType::Span), ("n", FieldType::Int)]);
        let b = Schema::of(&[("m", FieldType::Span)]);
        assert_eq!(a.index_of("n"), Some(1));
        assert_eq!(a.index_of("zz"), None);
        let c = a.concat(&b);
        assert_eq!(c.arity(), 3);
        assert_eq!(c.fields[2].name, "r_m");
    }

    #[test]
    fn display_forms() {
        let s = Schema::of(&[("m", FieldType::Span)]);
        assert_eq!(s.to_string(), "(m Span)");
        assert_eq!(Value::Bool(false).to_string(), "false");
    }
}
