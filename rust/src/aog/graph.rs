//! Operator-graph structure: nodes, kinds, validation, and the text dump.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::Arc;

use crate::dict::{AhoCorasick, Dictionary};
use crate::regex::CompiledRegex;
use crate::text::span::ConsolidatePolicy;

use super::expr::{Expr, TypeError};
use super::types::{Field, FieldType, Schema};

/// Node id — index into [`Graph::nodes`]. Construction keeps ids
/// topological (inputs always have smaller ids), which the executor,
/// partitioner and hardware compiler all rely on.
pub type NodeId = usize;

/// One output column of a [`OpKind::GroupAgg`] node, in select-list
/// order. `Key(j)` carries input column `j` through as a group key;
/// `Count` counts input rows per group; `CountDocs` counts the number of
/// *documents* that contributed at least one row to the group (the
/// document-frequency aggregate — per partial it advances at most once
/// per absorbed document, and partials add when merged).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AggCol {
    /// Pass input column `j` through as a group key.
    Key(usize),
    /// `Count()` — number of input rows in the group.
    Count,
    /// `CountDocs()` — number of documents with ≥1 row in the group.
    CountDocs,
}

/// Operator kinds. Extraction operators read the document; relational
/// operators transform tuple streams. `SubgraphExec` appears only after
/// partitioning: it stands for a hardware-offloaded subgraph in the
/// software supergraph (paper Fig 1b).
#[derive(Debug, Clone)]
pub enum OpKind {
    /// Emits one tuple per document: `(text: Span)` covering the whole doc.
    DocScan,
    /// Regex extraction over the document text.
    RegexExtract {
        regex: Arc<CompiledRegex>,
        /// Output column name.
        out: String,
    },
    /// Token-based dictionary extraction over the document text.
    DictExtract {
        dict: Arc<Dictionary>,
        matcher: Arc<AhoCorasick>,
        out: String,
    },
    /// Filter by predicate.
    Select { pred: Expr },
    /// Compute output columns (name, expr).
    Project { cols: Vec<(String, Expr)> },
    /// Binary nested-loop join with predicate over concatenated schema.
    Join { pred: Expr },
    /// Union of identically-shaped inputs.
    Union,
    /// Span consolidation on one column.
    Consolidate {
        col: usize,
        policy: ConsolidatePolicy,
    },
    /// Set difference: tuples of input 0 not present in input 1
    /// (SystemT's `minus`). Schemas must match.
    Difference,
    /// SystemT's BLOCK operator: group spans (column `col`, input sorted
    /// by that column) into blocks when consecutive spans are at most
    /// `max_gap` bytes apart; emit the covering span of each block with at
    /// least `min_size` members. Output schema: one span column.
    Block {
        col: usize,
        max_gap: u32,
        min_size: usize,
    },
    /// Order by columns (ascending, span/int/str order).
    Sort { keys: Vec<usize> },
    /// First n tuples.
    Limit { n: usize },
    /// Corpus-level hash aggregate (AQL `group by` + `Count()` /
    /// `CountDocs()`). Per document it behaves as a corpus of one (the
    /// full partial + finish over that document's rows); the executor
    /// additionally exports the per-document partial so the session can
    /// merge worker partials at finish time. Output columns follow the
    /// select-list order in `cols`; rows come out sorted by group key.
    GroupAgg { cols: Vec<(String, AggCol)> },
    /// Bounded top-k over an aggregate: score each input row with `score`
    /// (evaluated over the input schema, which carries no spans), keep the
    /// `k` best by score descending with ties broken by the group-key
    /// cells ascending (byte order for text). Output schema: input schema
    /// plus a trailing numeric `score` column.
    TopK { k: usize, score: Expr },
    /// Post-partition placeholder in the *supergraph*: run accelerator
    /// subgraph `subgraph_id` and emit the tuples of its `output_idx`-th
    /// output. Input 0 is always the DocScan (the document stream the
    /// accelerator consumes); inputs 1.. are software-computed tuple
    /// streams feeding the subgraph's `ExtInput` slots.
    SubgraphExec {
        subgraph_id: usize,
        output_idx: usize,
        /// Schema of the offloaded output node (set by the partitioner).
        schema: Schema,
    },
    /// Leaf inside a *subgraph body*: tuples injected by the runner from a
    /// software-computed input stream (slot index into the injected list).
    ExtInput { slot: usize, schema: Schema },
}

impl OpKind {
    /// Short operator name for profiles and dumps. The profiler groups by
    /// this (paper Fig 4 buckets: RegularExpression, Dictionary, relational
    /// operator names).
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::DocScan => "DocScan",
            OpKind::RegexExtract { .. } => "RegularExpression",
            OpKind::DictExtract { .. } => "Dictionary",
            OpKind::Select { .. } => "Select",
            OpKind::Project { .. } => "Project",
            OpKind::Join { .. } => "Join",
            OpKind::Union => "Union",
            OpKind::Consolidate { .. } => "Consolidate",
            OpKind::Difference => "Difference",
            OpKind::Block { .. } => "Block",
            OpKind::Sort { .. } => "Sort",
            OpKind::Limit { .. } => "Limit",
            OpKind::GroupAgg { .. } => "GroupAgg",
            OpKind::TopK { .. } => "TopK",
            OpKind::SubgraphExec { .. } => "SubgraphExec",
            OpKind::ExtInput { .. } => "ExtInput",
        }
    }

    /// True for the extraction operator family (the paper's
    /// "RegularExpression & Dictionaries" profile bucket).
    pub fn is_extraction(&self) -> bool {
        matches!(
            self,
            OpKind::RegexExtract { .. } | OpKind::DictExtract { .. }
        )
    }
}

/// One graph node.
#[derive(Debug, Clone)]
pub struct Node {
    /// Topological id (index into [`Graph::nodes`]).
    pub id: NodeId,
    /// The operator.
    pub kind: OpKind,
    /// Input node ids (all smaller than `id`).
    pub inputs: Vec<NodeId>,
    /// Output tuple schema.
    pub schema: Schema,
    /// View name, if this node is a named view's root.
    pub view: Option<String>,
}

/// The operator graph: a DAG with topological node ids and named outputs.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Nodes in topological order (`nodes[i].id == i`).
    pub nodes: Vec<Node>,
    /// `output view X;` targets: (view name, node id).
    pub outputs: Vec<(String, NodeId)>,
}

/// Graph construction/validation error. Every variant carries the node
/// id and (where one exists) the operator name ([`OpKind::name`]), so
/// callers — in particular [`crate::analysis`] diagnostics — can report
/// *which* operator failed, not just a bare message.
#[derive(Debug, Clone)]
pub enum GraphError {
    /// An input id does not precede the node (DAG order violated).
    BadInput {
        /// The node being added.
        node: NodeId,
        /// Operator name of the node being added.
        op: &'static str,
        /// The offending input id.
        input: NodeId,
    },
    /// An expression failed type checking.
    Type {
        /// The node being added.
        node: NodeId,
        /// Operator name of the node being added.
        op: &'static str,
        /// The underlying type error.
        err: TypeError,
    },
    /// Input schemas do not line up (union/difference arity, join shape).
    SchemaMismatch {
        /// The node being added.
        node: NodeId,
        /// Operator name of the node being added.
        op: &'static str,
        /// What mismatched.
        detail: String,
    },
    /// A column index is out of range for the input schema.
    BadColumn {
        /// The node being added.
        node: NodeId,
        /// Operator name of the node being added.
        op: &'static str,
        /// The offending column index.
        col: usize,
    },
    /// A span-consuming operator (block, consolidate) was pointed at a
    /// non-span column.
    SpanRequired {
        /// The node being added.
        node: NodeId,
        /// Operator name of the node being added.
        op: &'static str,
        /// The column that should have been a span.
        col: usize,
    },
    /// An output registration names a node the graph does not contain.
    DanglingOutput {
        /// The output view name.
        name: String,
        /// The referenced (missing) node id.
        node: NodeId,
        /// Number of nodes actually in the graph.
        len: usize,
    },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::BadInput { node, op, input } => {
                write!(f, "node {node} ({op}): input {input} is not an earlier node")
            }
            GraphError::Type { node, op, err } => write!(f, "node {node} ({op}): {err}"),
            GraphError::SchemaMismatch { node, op, detail } => {
                write!(f, "node {node} ({op}): schema mismatch: {detail}")
            }
            GraphError::BadColumn { node, op, col } => {
                write!(f, "node {node} ({op}): column {col} out of range")
            }
            GraphError::SpanRequired { node, op, col } => {
                write!(f, "node {node} ({op}): column {col} must be a span")
            }
            GraphError::DanglingOutput { name, node, len } => {
                write!(
                    f,
                    "output '{name}' references node {node}, but the graph has {len} nodes"
                )
            }
        }
    }
}

impl std::error::Error for GraphError {}

impl Graph {
    /// Create an empty graph.
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Append a node, computing its schema from the inputs. Inputs must
    /// already exist (topological construction).
    pub fn add(&mut self, kind: OpKind, inputs: Vec<NodeId>) -> Result<NodeId, GraphError> {
        let id = self.nodes.len();
        for &i in &inputs {
            if i >= id {
                return Err(GraphError::BadInput {
                    node: id,
                    op: kind.name(),
                    input: i,
                });
            }
        }
        let schema = self.derive_schema(id, &kind, &inputs)?;
        self.nodes.push(Node {
            id,
            kind,
            inputs,
            schema,
            view: None,
        });
        Ok(id)
    }

    /// Mark `node` as the root of view `name`.
    pub fn name_view(&mut self, node: NodeId, name: impl Into<String>) {
        self.nodes[node].view = Some(name.into());
    }

    /// Register an output view. Fails with
    /// [`GraphError::DanglingOutput`] if `node` is not in the graph, so a
    /// caller wiring outputs from a remap table gets the view name and
    /// the bad id back instead of an index panic.
    pub fn add_output(&mut self, name: impl Into<String>, node: NodeId) -> Result<(), GraphError> {
        let name = name.into();
        if node >= self.nodes.len() {
            return Err(GraphError::DanglingOutput {
                name,
                node,
                len: self.nodes.len(),
            });
        }
        self.nodes[node].view.get_or_insert_with(|| name.clone());
        self.outputs.push((name, node));
        Ok(())
    }

    /// Re-derive the schema of an existing node from its inputs,
    /// re-running every operator arity/type rule — the validation hook
    /// [`crate::analysis::check_graph`] uses to verify graphs produced by
    /// rebuilds (optimizer, partitioner, merges) rather than by [`Graph::add`].
    pub fn validate_node(&self, id: NodeId) -> Result<Schema, GraphError> {
        let n = &self.nodes[id];
        for &i in &n.inputs {
            if i >= id {
                return Err(GraphError::BadInput {
                    node: id,
                    op: n.kind.name(),
                    input: i,
                });
            }
        }
        self.derive_schema(id, &n.kind, &n.inputs)
    }

    /// Schema derivation (also the validator for operator/arity/type rules).
    fn derive_schema(
        &self,
        id: NodeId,
        kind: &OpKind,
        inputs: &[NodeId],
    ) -> Result<Schema, GraphError> {
        let op = kind.name();
        let input_schema = |k: usize| -> &Schema { &self.nodes[inputs[k]].schema };
        let expect_inputs = |n: usize| -> Result<(), GraphError> {
            if inputs.len() != n {
                Err(GraphError::SchemaMismatch {
                    node: id,
                    op,
                    detail: format!("expected {n} inputs, got {}", inputs.len()),
                })
            } else {
                Ok(())
            }
        };
        match kind {
            OpKind::DocScan => {
                expect_inputs(0)?;
                Ok(Schema::of(&[("text", FieldType::Span)]))
            }
            OpKind::RegexExtract { out, .. } | OpKind::DictExtract { out, .. } => {
                expect_inputs(1)?;
                // extraction reads the document; its input must expose a span
                // column (the doc text) — output is a single span column.
                if input_schema(0).fields.is_empty() {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: "extraction over empty schema".into(),
                    });
                }
                Ok(Schema {
                    fields: vec![Field {
                        name: out.clone(),
                        ty: FieldType::Span,
                    }],
                })
            }
            OpKind::Select { pred } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                match pred.infer_type(schema) {
                    Ok(FieldType::Bool) => Ok(schema.clone()),
                    Ok(t) => Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: format!("select predicate has type {t}, want Boolean"),
                    }),
                    Err(err) => Err(GraphError::Type { node: id, op, err }),
                }
            }
            OpKind::Project { cols } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                let mut fields = Vec::with_capacity(cols.len());
                for (name, e) in cols {
                    let ty = e
                        .infer_type(schema)
                        .map_err(|err| GraphError::Type { node: id, op, err })?;
                    fields.push(Field {
                        name: name.clone(),
                        ty,
                    });
                }
                Ok(Schema { fields })
            }
            OpKind::Join { pred } => {
                expect_inputs(2)?;
                let joined = input_schema(0).concat(input_schema(1));
                match pred.infer_type(&joined) {
                    Ok(FieldType::Bool) => Ok(joined),
                    Ok(t) => Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: format!("join predicate has type {t}, want Boolean"),
                    }),
                    Err(err) => Err(GraphError::Type { node: id, op, err }),
                }
            }
            OpKind::Union => {
                if inputs.is_empty() {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: "union needs at least one input".into(),
                    });
                }
                let first = input_schema(0).clone();
                for k in 1..inputs.len() {
                    let s = input_schema(k);
                    if s.arity() != first.arity()
                        || s.fields
                            .iter()
                            .zip(&first.fields)
                            .any(|(a, b)| a.ty != b.ty)
                    {
                        return Err(GraphError::SchemaMismatch {
                            node: id,
                            op,
                            detail: format!(
                                "union input {k} schema {s} incompatible with {first}"
                            ),
                        });
                    }
                }
                Ok(first)
            }
            OpKind::Difference => {
                expect_inputs(2)?;
                let (a, b) = (input_schema(0), input_schema(1));
                if a.arity() != b.arity()
                    || a.fields.iter().zip(&b.fields).any(|(x, y)| x.ty != y.ty)
                {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: format!("minus inputs {a} vs {b}"),
                    });
                }
                Ok(a.clone())
            }
            OpKind::Block { col, .. } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                if *col >= schema.arity() {
                    return Err(GraphError::BadColumn {
                        node: id,
                        op,
                        col: *col,
                    });
                }
                if schema.type_at(*col) != FieldType::Span {
                    return Err(GraphError::SpanRequired {
                        node: id,
                        op,
                        col: *col,
                    });
                }
                Ok(Schema::of(&[("block", FieldType::Span)]))
            }
            OpKind::Consolidate { col, .. } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                if *col >= schema.arity() {
                    return Err(GraphError::BadColumn {
                        node: id,
                        op,
                        col: *col,
                    });
                }
                if schema.type_at(*col) != FieldType::Span {
                    return Err(GraphError::SpanRequired {
                        node: id,
                        op,
                        col: *col,
                    });
                }
                Ok(schema.clone())
            }
            OpKind::Sort { keys } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                for &k in keys {
                    if k >= schema.arity() {
                        return Err(GraphError::BadColumn { node: id, op, col: k });
                    }
                }
                Ok(schema.clone())
            }
            OpKind::Limit { .. } => {
                expect_inputs(1)?;
                Ok(input_schema(0).clone())
            }
            OpKind::GroupAgg { cols } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                if !cols.iter().any(|(_, c)| matches!(c, AggCol::Key(_))) {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: "group by needs at least one key column".into(),
                    });
                }
                let mut fields = Vec::with_capacity(cols.len());
                for (name, c) in cols {
                    let ty = match c {
                        AggCol::Key(j) => {
                            if *j >= schema.arity() {
                                return Err(GraphError::BadColumn { node: id, op, col: *j });
                            }
                            let t = schema.type_at(*j);
                            match t {
                                FieldType::Str | FieldType::Int | FieldType::Bool => t,
                                other => {
                                    return Err(GraphError::SchemaMismatch {
                                        node: id,
                                        op,
                                        detail: format!(
                                            "group key '{name}' has type {other}; keys must \
                                             be Text, Integer or Boolean (use GetText on spans)"
                                        ),
                                    })
                                }
                            }
                        }
                        AggCol::Count | AggCol::CountDocs => FieldType::Int,
                    };
                    fields.push(Field {
                        name: name.clone(),
                        ty,
                    });
                }
                Ok(Schema { fields })
            }
            OpKind::TopK { k, score } => {
                expect_inputs(1)?;
                let schema = input_schema(0);
                if *k == 0 {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: "top k requires k >= 1".into(),
                    });
                }
                let ty = score
                    .infer_type(schema)
                    .map_err(|err| GraphError::Type { node: id, op, err })?;
                if !matches!(ty, FieldType::Int | FieldType::Float) {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: format!("score expression has type {ty}, want Integer or Float"),
                    });
                }
                let mut out = schema.clone();
                out.fields.push(Field {
                    name: "score".into(),
                    ty,
                });
                Ok(out)
            }
            OpKind::SubgraphExec { schema, .. } => {
                if inputs.is_empty() {
                    return Err(GraphError::SchemaMismatch {
                        node: id,
                        op,
                        detail: "SubgraphExec needs the DocScan as input 0".into(),
                    });
                }
                Ok(schema.clone())
            }
            OpKind::ExtInput { schema, .. } => {
                expect_inputs(0)?;
                Ok(schema.clone())
            }
        }
    }

    /// Merge `other` into this graph, appending its nodes (topological
    /// order is preserved) and its outputs, and **unifying `DocScan`**:
    /// `other`'s document scan maps onto this graph's existing one (or a
    /// fresh one if this graph has none), so every merged program reads
    /// the document stream through a single shared leaf — the first step
    /// of folding many queries into one supergraph. Identical extraction
    /// leaves are *not* interned here; that is the optimizer's
    /// [`dedup_extractions`](crate::optimizer::dedup_extractions) pass,
    /// which runs after all programs are merged.
    ///
    /// Returns the node remapping (`other` id → merged id).
    pub fn merge_from(&mut self, other: &Graph) -> Vec<NodeId> {
        let mut doc: Option<NodeId> = self
            .nodes
            .iter()
            .find(|n| matches!(n.kind, OpKind::DocScan))
            .map(|n| n.id);
        let mut remap: Vec<NodeId> = Vec::with_capacity(other.nodes.len());
        for node in &other.nodes {
            let id = if matches!(node.kind, OpKind::DocScan) {
                *doc.get_or_insert_with(|| {
                    self.add(OpKind::DocScan, vec![]).expect("DocScan cannot fail")
                })
            } else {
                let inputs: Vec<NodeId> = node.inputs.iter().map(|&i| remap[i]).collect();
                let id = self
                    .add(node.kind.clone(), inputs)
                    .expect("merging a valid graph preserves validity");
                if let Some(v) = &node.view {
                    self.name_view(id, v.clone());
                }
                id
            };
            remap.push(id);
        }
        for (name, target) in &other.outputs {
            self.add_output(name.clone(), remap[*target])
                .expect("remapped output targets a merged node");
        }
        remap
    }

    /// Number of extraction leaves (regex + dictionary operators) — the
    /// machine count a hardware image for this graph needs. Catalog tests
    /// assert that the merged supergraph's leaf count is *less* than the
    /// sum over independently compiled queries (shared patterns intern).
    pub fn extraction_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind.is_extraction()).count()
    }

    /// `ExtInput` slot → schema (`None` for slots no node references) —
    /// the single source of truth for every boundary that types
    /// row-shaped external injections (the executor's legacy entry, the
    /// accelerator runner), so placeholder semantics cannot drift.
    pub fn ext_input_schemas(&self) -> Vec<Option<Schema>> {
        let mut out: Vec<Option<Schema>> = Vec::new();
        for n in &self.nodes {
            if let OpKind::ExtInput { slot, schema } = &n.kind {
                if *slot >= out.len() {
                    out.resize(*slot + 1, None);
                }
                out[*slot] = Some(schema.clone());
            }
        }
        out
    }

    /// Downstream consumers of each node.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Nodes reachable (upstream) from the outputs — dead-node analysis for
    /// the optimizer.
    pub fn live_nodes(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<NodeId> = self.outputs.iter().map(|(_, n)| *n).collect();
        while let Some(n) = stack.pop() {
            if live[n] {
                continue;
            }
            live[n] = true;
            stack.extend(&self.nodes[n].inputs);
        }
        live
    }

    /// Human-readable AOG dump (the paper's Fig 1-style view of the graph).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        let view_of: HashMap<NodeId, &str> = self
            .nodes
            .iter()
            .filter_map(|n| n.view.as_deref().map(|v| (n.id, v)))
            .collect();
        for n in &self.nodes {
            let _ = write!(s, "  %{:<3} = {}(", n.id, n.kind.name());
            match &n.kind {
                OpKind::RegexExtract { regex, .. } => {
                    let _ = write!(s, "/{}/", regex.pattern.source);
                }
                OpKind::DictExtract { dict, .. } => {
                    let _ = write!(s, "'{}' [{} entries]", dict.name, dict.entries.len());
                }
                OpKind::Select { pred } => {
                    let _ = write!(s, "{pred}");
                }
                OpKind::Join { pred } => {
                    let _ = write!(s, "{pred}");
                }
                OpKind::Project { cols } => {
                    for (i, (name, e)) in cols.iter().enumerate() {
                        if i > 0 {
                            let _ = write!(s, ", ");
                        }
                        let _ = write!(s, "{name}={e}");
                    }
                }
                OpKind::Consolidate { col, policy } => {
                    let _ = write!(s, "${col} using {}", policy.name());
                }
                OpKind::Block { col, max_gap, min_size } => {
                    let _ = write!(s, "${col} gap {max_gap} min {min_size}");
                }
                OpKind::Sort { keys } => {
                    let _ = write!(s, "{keys:?}");
                }
                OpKind::Limit { n: k } => {
                    let _ = write!(s, "{k}");
                }
                OpKind::GroupAgg { cols } => {
                    for (i, (name, c)) in cols.iter().enumerate() {
                        if i > 0 {
                            let _ = write!(s, ", ");
                        }
                        match c {
                            AggCol::Key(j) => {
                                let _ = write!(s, "{name}=${j}");
                            }
                            AggCol::Count => {
                                let _ = write!(s, "{name}=Count()");
                            }
                            AggCol::CountDocs => {
                                let _ = write!(s, "{name}=CountDocs()");
                            }
                        }
                    }
                }
                OpKind::TopK { k, score } => {
                    let _ = write!(s, "k={k} score={score}");
                }
                OpKind::SubgraphExec {
                    subgraph_id,
                    output_idx,
                    ..
                } => {
                    let _ = write!(s, "#{subgraph_id}.{output_idx}");
                }
                OpKind::ExtInput { slot, .. } => {
                    let _ = write!(s, "slot {slot}");
                }
                _ => {}
            }
            let _ = write!(s, ")");
            if !n.inputs.is_empty() {
                let _ = write!(
                    s,
                    " <- {}",
                    n.inputs
                        .iter()
                        .map(|i| format!("%{i}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
            let _ = write!(s, "  :: {}", n.schema);
            if let Some(v) = view_of.get(&n.id) {
                let _ = write!(s, "  (view {v})");
            }
            let _ = writeln!(s);
        }
        for (name, node) in &self.outputs {
            let _ = writeln!(s, "  output {name} = %{node}");
        }
        s
    }

    /// Count nodes by operator family — used in tests and by the profiler.
    pub fn op_counts(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.kind.name()).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::expr::Func;
    use crate::dict::CaseMode;

    fn regex_node(pat: &str) -> OpKind {
        OpKind::RegexExtract {
            regex: Arc::new(crate::regex::compile(pat, false).unwrap()),
            out: "match".into(),
        }
    }

    fn dict_node(entries: &[&str]) -> OpKind {
        let d = Dictionary::new(
            "d",
            entries.iter().map(|s| s.to_string()).collect(),
            CaseMode::Insensitive,
        );
        let m = d.compile();
        OpKind::DictExtract {
            dict: Arc::new(d),
            matcher: Arc::new(m),
            out: "match".into(),
        }
    }

    #[test]
    fn build_simple_graph() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let re = g.add(regex_node(r"\d+"), vec![doc]).unwrap();
        let sel = g
            .add(
                OpKind::Select {
                    pred: Expr::Cmp(
                        Box::new(Expr::Call(Func::GetLength, vec![Expr::Col(0)])),
                        crate::aog::expr::CmpOp::Ge,
                        Box::new(Expr::LitInt(3)),
                    ),
                },
                vec![re],
            )
            .unwrap();
        g.add_output("Numbers", sel).unwrap();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[sel].schema.arity(), 1);
        assert!(g.dump().contains("RegularExpression"));
    }

    #[test]
    fn join_schema_concat() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a+"), vec![doc]).unwrap();
        let b = g.add(dict_node(&["bob"]), vec![doc]).unwrap();
        let j = g
            .add(
                OpKind::Join {
                    pred: Expr::Call(
                        Func::Follows,
                        vec![
                            Expr::Col(0),
                            Expr::Col(1),
                            Expr::LitInt(0),
                            Expr::LitInt(20),
                        ],
                    ),
                },
                vec![a, b],
            )
            .unwrap();
        assert_eq!(g.nodes[j].schema.arity(), 2);
    }

    #[test]
    fn union_schema_check() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        let b = g.add(regex_node("b"), vec![doc]).unwrap();
        let u = g.add(OpKind::Union, vec![a, b]).unwrap();
        assert_eq!(g.nodes[u].schema.arity(), 1);

        // incompatible union: project to int vs span
        let p = g
            .add(
                OpKind::Project {
                    cols: vec![(
                        "len".into(),
                        Expr::Call(Func::GetLength, vec![Expr::Col(0)]),
                    )],
                },
                vec![a],
            )
            .unwrap();
        assert!(g.add(OpKind::Union, vec![a, p]).is_err());
    }

    #[test]
    fn bad_predicate_type_rejected() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        let res = g.add(
            OpKind::Select {
                pred: Expr::LitInt(1),
            },
            vec![a],
        );
        assert!(res.is_err());
    }

    #[test]
    fn consolidate_requires_span_column() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        let p = g
            .add(
                OpKind::Project {
                    cols: vec![(
                        "len".into(),
                        Expr::Call(Func::GetLength, vec![Expr::Col(0)]),
                    )],
                },
                vec![a],
            )
            .unwrap();
        assert!(g
            .add(
                OpKind::Consolidate {
                    col: 0,
                    policy: ConsolidatePolicy::ContainedWithin
                },
                vec![p]
            )
            .is_err());
        assert!(g
            .add(
                OpKind::Consolidate {
                    col: 0,
                    policy: ConsolidatePolicy::ContainedWithin
                },
                vec![a]
            )
            .is_ok());
    }

    #[test]
    fn topological_input_enforced() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        assert!(g.add(OpKind::Union, vec![doc, 99]).is_err());
    }

    #[test]
    fn add_output_rejects_dangling_node() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        g.add_output("A", a).unwrap();
        let err = g.add_output("B", 99).unwrap_err();
        assert!(matches!(err, GraphError::DanglingOutput { node: 99, .. }));
        assert!(err.to_string().contains("'B'"), "{err}");
        // the failed registration must not leave a partial output behind
        assert_eq!(g.outputs.len(), 1);
    }

    #[test]
    fn errors_name_the_operator() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        let err = g
            .add(
                OpKind::Select {
                    pred: Expr::LitInt(1),
                },
                vec![a],
            )
            .unwrap_err();
        assert!(err.to_string().contains("(Select)"), "{err}");
    }

    #[test]
    fn validate_node_rederives_schemas() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        for n in 0..g.nodes.len() {
            let s = g.validate_node(n).unwrap();
            assert_eq!(s.arity(), g.nodes[n].schema.arity());
        }
        // corrupt the graph the way a buggy rebuild would: a forward input
        g.nodes[a].inputs = vec![a + 7];
        assert!(matches!(
            g.validate_node(a),
            Err(GraphError::BadInput { .. })
        ));
    }

    #[test]
    fn live_nodes_and_consumers() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("a"), vec![doc]).unwrap();
        let _dead = g.add(regex_node("b"), vec![doc]).unwrap();
        g.add_output("A", a).unwrap();
        let live = g.live_nodes();
        assert_eq!(live, vec![true, true, false]);
        let cons = g.consumers();
        assert_eq!(cons[doc].len(), 2);
        assert!(cons[a].is_empty());
    }

    #[test]
    fn merge_unifies_doc_scan_and_appends_outputs() {
        let mut a = Graph::new();
        let doc_a = a.add(OpKind::DocScan, vec![]).unwrap();
        let ra = a.add(regex_node("a+"), vec![doc_a]).unwrap();
        a.add_output("A", ra).unwrap();

        let mut b = Graph::new();
        let doc_b = b.add(OpKind::DocScan, vec![]).unwrap();
        let rb = b.add(regex_node("b+"), vec![doc_b]).unwrap();
        b.add_output("B", rb).unwrap();

        let remap = a.merge_from(&b);
        // exactly one DocScan survives; b's maps onto a's
        assert_eq!(a.op_counts()["DocScan"], 1);
        assert_eq!(remap[doc_b], doc_a);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.outputs[1].0, "B");
        assert_eq!(a.extraction_leaves(), 2);
        // merged graph stays valid: the merged regex's input is the
        // shared DocScan
        assert_eq!(a.nodes[remap[rb]].inputs, vec![doc_a]);
    }

    #[test]
    fn merge_into_empty_graph_creates_doc_scan() {
        let mut b = Graph::new();
        let doc_b = b.add(OpKind::DocScan, vec![]).unwrap();
        let rb = b.add(regex_node("x"), vec![doc_b]).unwrap();
        b.add_output("X", rb).unwrap();

        let mut a = Graph::new();
        let remap = a.merge_from(&b);
        assert_eq!(a.op_counts()["DocScan"], 1);
        assert_eq!(a.outputs.len(), 1);
        assert_eq!(a.nodes[remap[rb]].schema.arity(), 1);
    }

    #[test]
    fn group_agg_and_top_k_schemas() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(regex_node("[A-Z][a-z]+"), vec![doc]).unwrap();
        // keys must come in as Text/Int/Bool — project span -> text first
        let p = g
            .add(
                OpKind::Project {
                    cols: vec![(
                        "term".into(),
                        Expr::Call(Func::GetText, vec![Expr::Col(0)]),
                    )],
                },
                vec![a],
            )
            .unwrap();
        let agg = g
            .add(
                OpKind::GroupAgg {
                    cols: vec![
                        ("term".into(), AggCol::Key(0)),
                        ("n".into(), AggCol::Count),
                        ("docs".into(), AggCol::CountDocs),
                    ],
                },
                vec![p],
            )
            .unwrap();
        let s = &g.nodes[agg].schema;
        assert_eq!(s.arity(), 3);
        assert_eq!(s.type_at(0), FieldType::Str);
        assert_eq!(s.type_at(1), FieldType::Int);
        assert_eq!(s.type_at(2), FieldType::Int);

        let top = g
            .add(
                OpKind::TopK {
                    k: 5,
                    score: Expr::Col(1),
                },
                vec![agg],
            )
            .unwrap();
        let ts = &g.nodes[top].schema;
        assert_eq!(ts.arity(), 4);
        assert_eq!(ts.fields[3].name, "score");
        assert_eq!(ts.type_at(3), FieldType::Int);

        // validate_node re-derives the new kinds too
        for n in 0..g.nodes.len() {
            assert_eq!(g.validate_node(n).unwrap().arity(), g.nodes[n].schema.arity());
        }

        // rejected shapes: span group key, no keys, k = 0, non-numeric score
        assert!(g
            .add(
                OpKind::GroupAgg {
                    cols: vec![("m".into(), AggCol::Key(0)), ("n".into(), AggCol::Count)],
                },
                vec![a],
            )
            .is_err());
        assert!(g
            .add(
                OpKind::GroupAgg {
                    cols: vec![("n".into(), AggCol::Count)],
                },
                vec![p],
            )
            .is_err());
        assert!(g
            .add(
                OpKind::TopK {
                    k: 0,
                    score: Expr::Col(1),
                },
                vec![agg],
            )
            .is_err());
        assert!(g
            .add(
                OpKind::TopK {
                    k: 3,
                    score: Expr::Col(0),
                },
                vec![agg],
            )
            .is_err());

        let d = g.dump();
        assert!(d.contains("GroupAgg"), "{d}");
        assert!(d.contains("k=5"), "{d}");
    }

    #[test]
    fn dump_contains_outputs() {
        let mut g = Graph::new();
        let doc = g.add(OpKind::DocScan, vec![]).unwrap();
        let a = g.add(dict_node(&["ibm", "research"]), vec![doc]).unwrap();
        g.add_output("Orgs", a).unwrap();
        let d = g.dump();
        assert!(d.contains("Dictionary"), "{d}");
        assert!(d.contains("output Orgs"), "{d}");
        assert!(d.contains("2 entries"), "{d}");
    }
}
