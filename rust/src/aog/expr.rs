//! The scalar expression language used by Select/Project/Join predicates.
//!
//! AQL predicates over spans (`Follows`, `FollowsTok`, `Overlaps`,
//! `Contains`, ...) and scalar functions (`GetLength`, `GetText`,
//! `CombineSpans`, ...) are compiled into this small expression tree, which
//! is type-checked against the input schema at query-compile time — all
//! operator schemas are static, exactly as the paper requires for hardware
//! generation.

use std::fmt;
use std::sync::Arc;

use crate::text::{Span, TokenIndex};

use super::types::{FieldType, Schema, Tuple, Value};

/// Built-in functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Func {
    /// `GetBegin(span) -> int`
    GetBegin,
    /// `GetEnd(span) -> int`
    GetEnd,
    /// `GetLength(span) -> int` (bytes)
    GetLength,
    /// `GetText(span) -> str`
    GetText,
    /// `ToLowerCase(str) -> str`
    ToLowerCase,
    /// `Follows(a, b, min, max) -> bool`: b begins `min..=max` bytes after a ends
    Follows,
    /// `FollowsTok(a, b, min, max) -> bool`: token distance
    FollowsTok,
    /// `Overlaps(a, b) -> bool`
    Overlaps,
    /// `Contains(a, b) -> bool`: a contains b
    Contains,
    /// `ContainedWithin(a, b) -> bool`: a inside b
    ContainedWithin,
    /// `SpanEquals(a, b) -> bool`
    SpanEquals,
    /// `CombineSpans(a, b) -> span`
    CombineSpans,
    /// `SpanBetween(a, b) -> span`: the gap span from a.end to b.begin
    SpanBetween,
}

impl Func {
    /// Parse an AQL function name.
    pub fn parse(name: &str) -> Option<Func> {
        Some(match name {
            "GetBegin" => Func::GetBegin,
            "GetEnd" => Func::GetEnd,
            "GetLength" => Func::GetLength,
            "GetText" => Func::GetText,
            "ToLowerCase" => Func::ToLowerCase,
            "Follows" => Func::Follows,
            "FollowsTok" => Func::FollowsTok,
            "Overlaps" => Func::Overlaps,
            "Contains" => Func::Contains,
            "ContainedWithin" => Func::ContainedWithin,
            "SpanEquals" => Func::SpanEquals,
            "CombineSpans" => Func::CombineSpans,
            "SpanBetween" => Func::SpanBetween,
            _ => return None,
        })
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Func::GetBegin => "GetBegin",
            Func::GetEnd => "GetEnd",
            Func::GetLength => "GetLength",
            Func::GetText => "GetText",
            Func::ToLowerCase => "ToLowerCase",
            Func::Follows => "Follows",
            Func::FollowsTok => "FollowsTok",
            Func::Overlaps => "Overlaps",
            Func::Contains => "Contains",
            Func::ContainedWithin => "ContainedWithin",
            Func::SpanEquals => "SpanEquals",
            Func::CombineSpans => "CombineSpans",
            Func::SpanBetween => "SpanBetween",
        }
    }

    /// `(argument types, return type)`.
    pub fn signature(&self) -> (&'static [FieldType], FieldType) {
        use FieldType::*;
        match self {
            Func::GetBegin | Func::GetEnd | Func::GetLength => (&[Span], Int),
            Func::GetText => (&[Span], Str),
            Func::ToLowerCase => (&[Str], Str),
            Func::Follows | Func::FollowsTok => (&[Span, Span, Int, Int], Bool),
            Func::Overlaps
            | Func::Contains
            | Func::ContainedWithin
            | Func::SpanEquals => (&[Span, Span], Bool),
            Func::CombineSpans | Func::SpanBetween => (&[Span, Span], Span),
        }
    }

    /// True if the accelerator's relational post-stage can evaluate this
    /// function (used by the partitioner's hardware-support classification).
    /// `GetText`/`ToLowerCase` require string materialization, which the
    /// streaming datapath does not do.
    pub fn hw_supported(&self) -> bool {
        !matches!(self, Func::GetText | Func::ToLowerCase)
    }
}

/// Comparison operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// `=`.
    Eq,
    /// `!=`.
    Ne,
    /// `<`.
    Lt,
    /// `<=`.
    Le,
    /// `>`.
    Gt,
    /// `>=`.
    Ge,
}

impl CmpOp {
    /// AQL surface syntax.
    pub fn symbol(&self) -> &'static str {
        match self {
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        }
    }
}

/// An expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Input column by position.
    Col(usize),
    /// Integer literal.
    LitInt(i64),
    /// String literal, interned once at compile time so evaluation is a
    /// refcount bump instead of a per-row allocation.
    LitStr(Arc<str>),
    /// Boolean literal.
    LitBool(bool),
    /// Binary comparison.
    Cmp(Box<Expr>, CmpOp, Box<Expr>),
    /// Logical conjunction.
    And(Box<Expr>, Box<Expr>),
    /// Logical disjunction.
    Or(Box<Expr>, Box<Expr>),
    /// Logical negation.
    Not(Box<Expr>),
    /// Built-in function call.
    Call(Func, Vec<Expr>),
}

/// Type error found during expression checking.
#[derive(Debug, Clone)]
pub struct TypeError(pub String);

impl fmt::Display for TypeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "type error: {}", self.0)
    }
}

impl std::error::Error for TypeError {}

/// Evaluation context: the document the tuple's spans point into.
pub struct EvalCtx<'a> {
    /// The document text spans point into.
    pub text: &'a str,
    /// The document's token index (for token-distance predicates).
    pub tokens: &'a TokenIndex,
}

/// Positional row access for expression evaluation. Implemented by the
/// legacy [`Tuple`] (a row of owned values) and by the columnar cursors
/// ([`TupleRef`](crate::exec::batch::TupleRef) /
/// [`JoinRow`](crate::exec::batch::JoinRow)), so a single evaluator serves
/// both storage layouts.
pub trait RowAccess {
    /// The value of column `i` (owned; spans/ints copy, strings bump a
    /// refcount).
    fn value_at(&self, i: usize) -> Value;
}

impl RowAccess for Tuple {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        self[i].clone()
    }
}

impl<R: RowAccess + ?Sized> RowAccess for &R {
    #[inline]
    fn value_at(&self, i: usize) -> Value {
        (**self).value_at(i)
    }
}

/// Largest builtin-function arity ([`Func::signature`]); lets `Call`
/// evaluation stage its arguments on the stack instead of a per-row `Vec`.
const MAX_FUNC_ARGS: usize = 4;

impl Expr {
    /// Infer the expression's type against `schema`, or fail.
    pub fn infer_type(&self, schema: &Schema) -> Result<FieldType, TypeError> {
        match self {
            Expr::Col(i) => {
                if *i >= schema.arity() {
                    return Err(TypeError(format!(
                        "column {} out of range for schema {}",
                        i, schema
                    )));
                }
                Ok(schema.type_at(*i))
            }
            Expr::LitInt(_) => Ok(FieldType::Int),
            Expr::LitStr(_) => Ok(FieldType::Str),
            Expr::LitBool(_) => Ok(FieldType::Bool),
            Expr::Cmp(a, _, b) => {
                let ta = a.infer_type(schema)?;
                let tb = b.infer_type(schema)?;
                if ta != tb {
                    return Err(TypeError(format!(
                        "comparison between {ta} and {tb}"
                    )));
                }
                Ok(FieldType::Bool)
            }
            Expr::And(a, b) | Expr::Or(a, b) => {
                for (side, e) in [("lhs", a), ("rhs", b)] {
                    if e.infer_type(schema)? != FieldType::Bool {
                        return Err(TypeError(format!("{side} of and/or is not boolean")));
                    }
                }
                Ok(FieldType::Bool)
            }
            Expr::Not(a) => {
                if a.infer_type(schema)? != FieldType::Bool {
                    return Err(TypeError("operand of 'not' is not boolean".into()));
                }
                Ok(FieldType::Bool)
            }
            Expr::Call(f, args) => {
                let (params, ret) = f.signature();
                if args.len() != params.len() {
                    return Err(TypeError(format!(
                        "{} expects {} args, got {}",
                        f.name(),
                        params.len(),
                        args.len()
                    )));
                }
                for (i, (a, want)) in args.iter().zip(params).enumerate() {
                    let got = a.infer_type(schema)?;
                    if got != *want {
                        return Err(TypeError(format!(
                            "{} arg {} is {got}, expected {want}",
                            f.name(),
                            i
                        )));
                    }
                }
                Ok(ret)
            }
        }
    }

    /// Evaluate against a row (legacy [`Tuple`] or a columnar cursor —
    /// anything implementing [`RowAccess`]). Expressions are type-checked
    /// at compile time, so value-kind mismatches here panic (engine bug).
    pub fn eval<R: RowAccess>(&self, row: &R, ctx: &EvalCtx<'_>) -> Value {
        match self {
            Expr::Col(i) => row.value_at(*i),
            Expr::LitInt(v) => Value::Int(*v),
            Expr::LitStr(s) => Value::Str(s.clone()),
            Expr::LitBool(b) => Value::Bool(*b),
            Expr::Cmp(a, op, b) => {
                let va = a.eval(row, ctx);
                let vb = b.eval(row, ctx);
                Value::Bool(compare(&va, *op, &vb))
            }
            Expr::And(a, b) => {
                Value::Bool(a.eval(row, ctx).as_bool() && b.eval(row, ctx).as_bool())
            }
            Expr::Or(a, b) => {
                Value::Bool(a.eval(row, ctx).as_bool() || b.eval(row, ctx).as_bool())
            }
            Expr::Not(a) => Value::Bool(!a.eval(row, ctx).as_bool()),
            Expr::Call(f, args) => {
                // arguments staged on the stack: this runs once per row on
                // the executor's hot path and must not touch the allocator
                if args.len() <= MAX_FUNC_ARGS {
                    let mut vals: [Value; MAX_FUNC_ARGS] =
                        [Value::Null, Value::Null, Value::Null, Value::Null];
                    for (i, a) in args.iter().enumerate() {
                        vals[i] = a.eval(row, ctx);
                    }
                    eval_func(*f, &vals[..args.len()], ctx)
                } else {
                    let vals: Vec<Value> = args.iter().map(|a| a.eval(row, ctx)).collect();
                    eval_func(*f, &vals, ctx)
                }
            }
        }
    }

    /// Collect referenced column indices (for pushdown analysis).
    pub fn columns(&self, out: &mut Vec<usize>) {
        match self {
            Expr::Col(i) => out.push(*i),
            Expr::LitInt(_) | Expr::LitStr(_) | Expr::LitBool(_) => {}
            Expr::Cmp(a, _, b) | Expr::And(a, b) | Expr::Or(a, b) => {
                a.columns(out);
                b.columns(out);
            }
            Expr::Not(a) => a.columns(out),
            Expr::Call(_, args) => {
                for a in args {
                    a.columns(out);
                }
            }
        }
    }

    /// Rewrite column indices through `map` (old index → new index).
    pub fn remap_columns(&self, map: &dyn Fn(usize) -> usize) -> Expr {
        match self {
            Expr::Col(i) => Expr::Col(map(*i)),
            Expr::LitInt(_) | Expr::LitStr(_) | Expr::LitBool(_) => self.clone(),
            Expr::Cmp(a, op, b) => Expr::Cmp(
                Box::new(a.remap_columns(map)),
                *op,
                Box::new(b.remap_columns(map)),
            ),
            Expr::And(a, b) => Expr::And(
                Box::new(a.remap_columns(map)),
                Box::new(b.remap_columns(map)),
            ),
            Expr::Or(a, b) => Expr::Or(
                Box::new(a.remap_columns(map)),
                Box::new(b.remap_columns(map)),
            ),
            Expr::Not(a) => Expr::Not(Box::new(a.remap_columns(map))),
            Expr::Call(f, args) => {
                Expr::Call(*f, args.iter().map(|a| a.remap_columns(map)).collect())
            }
        }
    }

    /// True if every function used is hardware-supported.
    pub fn hw_supported(&self) -> bool {
        match self {
            Expr::Col(_) | Expr::LitInt(_) | Expr::LitStr(_) | Expr::LitBool(_) => true,
            Expr::Cmp(a, _, b) | Expr::And(a, b) | Expr::Or(a, b) => {
                a.hw_supported() && b.hw_supported()
            }
            Expr::Not(a) => a.hw_supported(),
            Expr::Call(f, args) => f.hw_supported() && args.iter().all(|a| a.hw_supported()),
        }
    }
}

fn compare(a: &Value, op: CmpOp, b: &Value) -> bool {
    use std::cmp::Ordering;
    let ord = match (a, b) {
        (Value::Int(x), Value::Int(y)) => x.cmp(y),
        (Value::Str(x), Value::Str(y)) => x.cmp(y),
        (Value::Bool(x), Value::Bool(y)) => x.cmp(y),
        (Value::Span(x), Value::Span(y)) => x.cmp(y),
        (Value::Float(x), Value::Float(y)) => {
            x.partial_cmp(y).unwrap_or(Ordering::Equal)
        }
        _ => panic!("comparison of mismatched values {a:?} vs {b:?}"),
    };
    match op {
        CmpOp::Eq => ord == Ordering::Equal,
        CmpOp::Ne => ord != Ordering::Equal,
        CmpOp::Lt => ord == Ordering::Less,
        CmpOp::Le => ord != Ordering::Greater,
        CmpOp::Gt => ord == Ordering::Greater,
        CmpOp::Ge => ord != Ordering::Less,
    }
}

fn eval_func(f: Func, vals: &[Value], ctx: &EvalCtx<'_>) -> Value {
    match f {
        Func::GetBegin => Value::Int(vals[0].as_span().begin as i64),
        Func::GetEnd => Value::Int(vals[0].as_span().end as i64),
        Func::GetLength => Value::Int(vals[0].as_span().len() as i64),
        Func::GetText => Value::Str(Arc::from(vals[0].as_span().text(ctx.text))),
        Func::ToLowerCase => Value::Str(Arc::from(vals[0].as_str().to_ascii_lowercase())),
        Func::Follows => {
            let (a, b) = (vals[0].as_span(), vals[1].as_span());
            let (min, max) = (vals[2].as_int().max(0) as u32, vals[3].as_int().max(0) as u32);
            Value::Bool(a.follows(&b, min, max))
        }
        Func::FollowsTok => {
            let (a, b) = (vals[0].as_span(), vals[1].as_span());
            let (min, max) = (vals[2].as_int().max(0), vals[3].as_int().max(0));
            if b.begin < a.end {
                return Value::Bool(false);
            }
            let d = ctx.tokens.tokens_between(a.end, b.begin) as i64;
            Value::Bool(d >= min && d <= max)
        }
        Func::Overlaps => {
            Value::Bool(vals[0].as_span().overlaps(&vals[1].as_span()))
        }
        Func::Contains => {
            Value::Bool(vals[0].as_span().contains(&vals[1].as_span()))
        }
        Func::ContainedWithin => {
            Value::Bool(vals[1].as_span().contains(&vals[0].as_span()))
        }
        Func::SpanEquals => Value::Bool(vals[0].as_span() == vals[1].as_span()),
        Func::CombineSpans => Value::Span(vals[0].as_span().combine(&vals[1].as_span())),
        Func::SpanBetween => {
            let (a, b) = (vals[0].as_span(), vals[1].as_span());
            let begin = a.end.min(b.begin);
            let end = b.begin.max(a.end);
            Value::Span(Span::new(begin, end))
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Col(i) => write!(f, "${i}"),
            Expr::LitInt(v) => write!(f, "{v}"),
            Expr::LitStr(s) => write!(f, "{s:?}"),
            Expr::LitBool(b) => write!(f, "{b}"),
            Expr::Cmp(a, op, b) => write!(f, "({a} {} {b})", op.symbol()),
            Expr::And(a, b) => write!(f, "({a} and {b})"),
            Expr::Or(a, b) => write!(f, "({a} or {b})"),
            Expr::Not(a) => write!(f, "(not {a})"),
            Expr::Call(func, args) => {
                write!(f, "{}(", func.name())?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::Tokenizer;

    fn ctx_for(text: &'static str) -> (EvalCtx<'static>, &'static TokenIndex) {
        let tokens = Box::leak(Box::new(Tokenizer::standard().tokenize(text)));
        (
            EvalCtx {
                text,
                tokens,
            },
            tokens,
        )
    }

    fn span_tuple(pairs: &[(u32, u32)]) -> Tuple {
        pairs
            .iter()
            .map(|&(b, e)| Value::Span(Span::new(b, e)))
            .collect()
    }

    #[test]
    fn span_getters() {
        let (ctx, _) = ctx_for("hello world");
        let t = span_tuple(&[(6, 11)]);
        let e = Expr::Call(Func::GetText, vec![Expr::Col(0)]);
        assert_eq!(e.eval(&t, &ctx), Value::Str("world".into()));
        let e = Expr::Call(Func::GetLength, vec![Expr::Col(0)]);
        assert_eq!(e.eval(&t, &ctx), Value::Int(5));
        let e = Expr::Call(Func::GetBegin, vec![Expr::Col(0)]);
        assert_eq!(e.eval(&t, &ctx), Value::Int(6));
    }

    #[test]
    fn follows_predicates() {
        let (ctx, _) = ctx_for("aa bb cc dd");
        let t = span_tuple(&[(0, 2), (6, 8)]); // "aa" and "cc"
        let follows = Expr::Call(
            Func::Follows,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(0), Expr::LitInt(10)],
        );
        assert_eq!(follows.eval(&t, &ctx), Value::Bool(true));
        let follows_tok = Expr::Call(
            Func::FollowsTok,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(1), Expr::LitInt(1)],
        );
        // exactly one token ("bb") between them
        assert_eq!(follows_tok.eval(&t, &ctx), Value::Bool(true));
        let follows_tok0 = Expr::Call(
            Func::FollowsTok,
            vec![Expr::Col(0), Expr::Col(1), Expr::LitInt(0), Expr::LitInt(0)],
        );
        assert_eq!(follows_tok0.eval(&t, &ctx), Value::Bool(false));
    }

    #[test]
    fn span_relations() {
        let (ctx, _) = ctx_for("abcdefghij");
        let t = span_tuple(&[(0, 6), (2, 4)]);
        for (f, want) in [
            (Func::Contains, true),
            (Func::ContainedWithin, false),
            (Func::Overlaps, true),
            (Func::SpanEquals, false),
        ] {
            let e = Expr::Call(f, vec![Expr::Col(0), Expr::Col(1)]);
            assert_eq!(e.eval(&t, &ctx), Value::Bool(want), "{}", f.name());
        }
        let e = Expr::Call(Func::CombineSpans, vec![Expr::Col(1), Expr::Col(0)]);
        assert_eq!(e.eval(&t, &ctx), Value::Span(Span::new(0, 6)));
    }

    #[test]
    fn span_between_gap() {
        let (ctx, _) = ctx_for("aa bb cc");
        let t = span_tuple(&[(0, 2), (6, 8)]);
        let e = Expr::Call(Func::SpanBetween, vec![Expr::Col(0), Expr::Col(1)]);
        assert_eq!(e.eval(&t, &ctx), Value::Span(Span::new(2, 6)));
    }

    #[test]
    fn boolean_logic_and_compare() {
        let (ctx, _) = ctx_for("x");
        let t: Tuple = vec![Value::Int(5)];
        let e = Expr::And(
            Box::new(Expr::Cmp(
                Box::new(Expr::Col(0)),
                CmpOp::Gt,
                Box::new(Expr::LitInt(3)),
            )),
            Box::new(Expr::Not(Box::new(Expr::Cmp(
                Box::new(Expr::Col(0)),
                CmpOp::Eq,
                Box::new(Expr::LitInt(9)),
            )))),
        );
        assert_eq!(e.eval(&t, &ctx), Value::Bool(true));
    }

    #[test]
    fn type_inference_ok_and_errors() {
        let schema = Schema::of(&[("m", FieldType::Span), ("n", FieldType::Int)]);
        let ok = Expr::Call(Func::GetLength, vec![Expr::Col(0)]);
        assert_eq!(ok.infer_type(&schema).unwrap(), FieldType::Int);

        let bad_arg = Expr::Call(Func::GetLength, vec![Expr::Col(1)]);
        assert!(bad_arg.infer_type(&schema).is_err());

        let bad_count = Expr::Call(Func::Overlaps, vec![Expr::Col(0)]);
        assert!(bad_count.infer_type(&schema).is_err());

        let bad_col = Expr::Col(7);
        assert!(bad_col.infer_type(&schema).is_err());

        let bad_cmp = Expr::Cmp(
            Box::new(Expr::Col(0)),
            CmpOp::Eq,
            Box::new(Expr::LitInt(1)),
        );
        assert!(bad_cmp.infer_type(&schema).is_err());

        let bad_and = Expr::And(Box::new(Expr::LitInt(1)), Box::new(Expr::LitBool(true)));
        assert!(bad_and.infer_type(&schema).is_err());
    }

    #[test]
    fn columns_and_remap() {
        let e = Expr::Call(
            Func::Follows,
            vec![Expr::Col(0), Expr::Col(2), Expr::LitInt(0), Expr::LitInt(5)],
        );
        let mut cols = Vec::new();
        e.columns(&mut cols);
        assert_eq!(cols, vec![0, 2]);
        let r = e.remap_columns(&|i| i + 10);
        let mut cols2 = Vec::new();
        r.columns(&mut cols2);
        assert_eq!(cols2, vec![10, 12]);
    }

    #[test]
    fn hw_support_classification() {
        let ok = Expr::Call(Func::Overlaps, vec![Expr::Col(0), Expr::Col(1)]);
        assert!(ok.hw_supported());
        let no = Expr::Cmp(
            Box::new(Expr::Call(Func::GetText, vec![Expr::Col(0)])),
            CmpOp::Eq,
            Box::new(Expr::LitStr("x".into())),
        );
        assert!(!no.hw_supported());
    }

    #[test]
    fn func_parse_roundtrip() {
        for f in [
            Func::GetBegin,
            Func::Follows,
            Func::FollowsTok,
            Func::CombineSpans,
            Func::SpanBetween,
        ] {
            assert_eq!(Func::parse(f.name()), Some(f));
        }
        assert_eq!(Func::parse("Bogus"), None);
    }

    #[test]
    fn display_roundtrippable_shape() {
        let e = Expr::And(
            Box::new(Expr::Call(Func::Overlaps, vec![Expr::Col(0), Expr::Col(1)])),
            Box::new(Expr::LitBool(true)),
        );
        assert_eq!(e.to_string(), "(Overlaps($0, $1) and true)");
    }
}
