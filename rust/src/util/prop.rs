//! Minimal property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides the
//! subset we need: run a property over many PRNG-generated cases, and on
//! failure greedily shrink the input before reporting. Generators are plain
//! closures over [`Prng`]; shrinking is type-directed via the [`Shrink`]
//! trait.

use super::prng::Prng;

/// Number of cases per property unless overridden.
pub const DEFAULT_CASES: usize = 256;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for String {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.chars().count();
        if n == 0 {
            return out;
        }
        // halves
        let chars: Vec<char> = self.chars().collect();
        out.push(chars[..n / 2].iter().collect());
        out.push(chars[n / 2..].iter().collect());
        // drop one char at a few positions
        for i in [0, n / 2, n - 1] {
            let mut c = chars.clone();
            c.remove(i.min(n - 1));
            out.push(c.into_iter().collect());
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        for i in [0, n / 2, n - 1] {
            let mut v = self.clone();
            v.remove(i.min(n - 1));
            out.push(v);
        }
        // element-wise shrink of the first element
        if let Some(shrunk) = self[0].shrink().into_iter().next() {
            let mut v = self.clone();
            v[0] = shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` over `cases` inputs drawn from `gen`; on failure, shrink and
/// panic with the minimal counterexample. `seed` keeps runs reproducible.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Prng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_failure(input, &mut prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample: {minimal:?}"
            );
        }
    }
}

/// Like [`check`] with [`DEFAULT_CASES`].
pub fn check_default<T, G, P>(seed: u64, gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Prng) -> T,
    P: FnMut(&T) -> bool,
{
    check(seed, DEFAULT_CASES, gen, prop)
}

fn shrink_failure<T: Shrink, P: FnMut(&T) -> bool>(mut worst: T, prop: &mut P) -> T {
    // Greedy descent: keep taking the first still-failing shrink candidate.
    let mut budget = 1000usize;
    'outer: while budget > 0 {
        for cand in worst.shrink() {
            budget -= 1;
            if !prop(&cand) {
                worst = cand;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    worst
}

/// Document texts for work-package round-trip properties: lengths biased
/// toward the cases that break packing — empty documents, single bytes,
/// exact block fits, and one-short-of-block (the NUL-separator edge: an
/// exact fit leaves no room for the separator byte) — over a small,
/// matcher-relevant alphabet. NUL never appears (it is the package
/// separator, reserved by the corpus contract).
pub fn packing_corpus(
    rng: &mut Prng,
    max_docs: usize,
    block: usize,
    alphabet: &[u8],
) -> Vec<String> {
    debug_assert!(block >= 2);
    debug_assert!(alphabet.iter().all(|&b| b != 0), "NUL is reserved");
    // range() is half-open, so +1 keeps max_docs reachable
    let n = rng.range(1, max_docs.max(1) + 1);
    (0..n)
        .map(|_| {
            let len = match rng.below(10) {
                0 => 0,
                1 => 1,
                2 => block,
                3 => block - 1,
                _ => rng.below((block / 8).clamp(2, 128)),
            };
            rng.string_over(alphabet, len)
        })
        .collect()
}

/// Generate a random ASCII string (printable subset) of length `< max_len`.
pub fn ascii_string(rng: &mut Prng, max_len: usize) -> String {
    let len = rng.below(max_len.max(1));
    (0..len).map(|_| rng.printable() as char).collect()
}

/// Generate a random lowercase word of length in `[1, max_len]`.
pub fn word(rng: &mut Prng, max_len: usize) -> String {
    let len = rng.range(1, max_len + 1);
    (0..len).map(|_| rng.lower()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 64, |r| ascii_string(r, 32), |s| s.len() < 32);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        // Property "no 'x' anywhere" fails and should shrink towards a short
        // string still containing 'x'.
        check(
            2,
            512,
            |r| {
                let mut s = ascii_string(r, 16);
                if r.chance(0.2) {
                    s.push('x');
                }
                s
            },
            |s| !s.contains('x'),
        );
    }

    #[test]
    fn packing_corpus_profile() {
        let mut rng = Prng::new(3);
        let mut saw_empty = false;
        let mut saw_boundary = false;
        for _ in 0..200 {
            for t in packing_corpus(&mut rng, 8, 64, b"ab c") {
                assert!(t.len() <= 64);
                assert!(!t.bytes().any(|b| b == 0));
                saw_empty |= t.is_empty();
                saw_boundary |= t.len() >= 63;
            }
        }
        assert!(saw_empty, "the edge-case mix must include empty documents");
        assert!(saw_boundary, "the mix must include block-boundary documents");
    }

    #[test]
    fn shrink_string_smaller() {
        let s = "hello".to_string();
        for c in s.shrink() {
            assert!(c.len() < s.len());
        }
    }

    #[test]
    fn shrink_usize_terminates() {
        let mut v = 1000usize;
        let mut steps = 0;
        while let Some(next) = v.shrink().into_iter().next() {
            v = next;
            steps += 1;
            assert!(steps < 10_000);
            if v == 0 {
                break;
            }
        }
        assert_eq!(v, 0);
    }
}
