//! Small shared utilities: a deterministic PRNG (no `rand` available in the
//! offline vendor set), a minimal property-testing harness standing in for
//! `proptest`, and misc helpers.

#[cfg(feature = "bench-alloc")]
pub mod alloc;
pub mod prng;
pub mod prop;

pub use prng::Prng;

/// Format a byte-throughput as a human-readable string (MB/s).
pub fn fmt_mbps(bytes_per_sec: f64) -> String {
    format!("{:8.1} MB/s", bytes_per_sec / 1.0e6)
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn fmt_mbps_shape() {
        assert!(fmt_mbps(500.0e6).contains("500.0 MB/s"));
    }
}
