//! Deterministic xorshift64* PRNG.
//!
//! The offline vendor set has no `rand` crate, and determinism is a feature
//! here anyway: corpora, property tests and benchmarks must be reproducible
//! run-to-run so that EXPERIMENTS.md numbers can be regenerated.

/// A xorshift64* generator. Not cryptographic; statistically fine for
/// corpus synthesis and property testing.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Create a generator from a non-zero seed (zero is mapped to a fixed
    /// odd constant; xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Prng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (half-open). `hi` must be > `lo`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Random lowercase ASCII letter.
    #[inline]
    pub fn lower(&mut self) -> char {
        (b'a' + self.below(26) as u8) as char
    }

    /// Random ASCII digit.
    #[inline]
    pub fn digit(&mut self) -> char {
        (b'0' + self.below(10) as u8) as char
    }

    /// Random printable ASCII byte (0x20..=0x7E).
    #[inline]
    pub fn printable(&mut self) -> u8 {
        0x20 + self.below(0x5F) as u8
    }

    /// Random ASCII string of length `len` over the given alphabet.
    pub fn string_over(&mut self, alphabet: &[u8], len: usize) -> String {
        (0..len)
            .map(|_| *self.pick(alphabet) as char)
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut p = Prng::new(0);
        let v: Vec<u64> = (0..4).map(|_| p.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let x = p.below(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut p = Prng::new(11);
        for _ in 0..1000 {
            let x = p.range(5, 10);
            assert!((5..10).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(3);
        let mut v: Vec<usize> = (0..32).collect();
        p.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn distribution_roughly_uniform() {
        let mut p = Prng::new(123);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[p.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "bucket count {c} out of range");
        }
    }
}
