//! Allocation-counting global allocator, behind the `bench-alloc` feature.
//!
//! `repro bench` and the columnar regression tests use it to turn
//! "allocations per document" into a measured, CI-checkable number: the
//! whole point of the columnar executor is that a warmed-up worker thread
//! serves a document from recycled arena buffers, so the steady-state
//! count must stay a small constant (and ~an order of magnitude below the
//! legacy row pipeline's).
//!
//! The counter is global and monotonic; callers sample
//! [`allocations`] before/after a measured region and difference the two.
//! Only allocation *events* are counted (alloc, alloc_zeroed, realloc) —
//! frees are not, since the metric of interest is allocator pressure.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// A [`System`] wrapper that counts allocation events. Installed as the
/// crate's `#[global_allocator]` when `bench-alloc` is enabled.
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocation events (process-wide, all threads) since start.
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// The steady-state measurement protocol, shared by `repro bench` and the
/// columnar regression tests so the committed benchmark number and the CI
/// assertion can never drift apart: run `pass` once unmeasured (arena /
/// cache warm-up), then `reps` more times measured, and return mean
/// allocation events per unit (`units_per_pass` units per pass — e.g.
/// documents per corpus sweep).
///
/// The counter is process-global: callers must ensure no other thread is
/// allocating during the measured window (single-threaded `run_doc`
/// loops, `--test-threads=1` in CI).
pub fn allocations_per_unit(mut pass: impl FnMut(), reps: usize, units_per_pass: usize) -> f64 {
    pass(); // warm-up, unmeasured
    let a0 = allocations();
    for _ in 0..reps.max(1) {
        pass();
    }
    (allocations() - a0) as f64 / (reps.max(1) * units_per_pass.max(1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotonic_and_counts() {
        let a0 = allocations();
        let v: Vec<u64> = (0..64).collect();
        std::hint::black_box(&v);
        let a1 = allocations();
        assert!(a1 > a0, "allocating a Vec must tick the counter");
    }
}
