//! Thompson NFA construction.
//!
//! States carry at most one byte-class transition plus epsilon edges; the
//! construction is the classic one, with bounded repetition expanded (the
//! parser caps `{m,n}` at 64 so expansion stays small). The NFA is an
//! intermediate form only — both execution paths run DFAs.

use super::ast::{Ast, ByteClass, Pattern};

/// NFA state id.
pub type StateId = u32;

/// One NFA state.
#[derive(Debug, Clone)]
pub struct NfaState {
    /// Byte transition, if any.
    pub on_byte: Option<(ByteClass, StateId)>,
    /// Epsilon successors.
    pub eps: Vec<StateId>,
    /// Accepting?
    pub accept: bool,
}

/// A Thompson NFA with a single start state and explicit accept flags.
#[derive(Debug, Clone)]
pub struct Nfa {
    /// All states, indexed by [`StateId`].
    pub states: Vec<NfaState>,
    /// The single start state.
    pub start: StateId,
}

impl Nfa {
    fn push(&mut self) -> StateId {
        self.states.push(NfaState {
            on_byte: None,
            eps: Vec::new(),
            accept: false,
        });
        (self.states.len() - 1) as StateId
    }

    /// Build the NFA for a pattern body. If `reverse` is set, the AST is
    /// mirrored first (concatenations reversed, recursively) — the reverse
    /// NFA/DFA recovers match *starts* by scanning backwards from a
    /// hardware-reported match end.
    pub fn build(pattern: &Pattern, reverse: bool) -> Nfa {
        let ast = if reverse {
            reverse_ast(&pattern.ast)
        } else {
            pattern.ast.clone()
        };
        let mut nfa = Nfa {
            states: Vec::new(),
            start: 0,
        };
        let start = nfa.push();
        let accept = nfa.push();
        nfa.states[accept as usize].accept = true;
        nfa.start = start;
        nfa.compile(&ast, start, accept);
        nfa
    }

    /// Wire `ast` between `from` and `to`.
    fn compile(&mut self, ast: &Ast, from: StateId, to: StateId) {
        match ast {
            Ast::Empty => self.states[from as usize].eps.push(to),
            Ast::Class(c) => {
                // A state can hold only one byte transition; if `from`
                // already has one, interpose an epsilon hop.
                let src = if self.states[from as usize].on_byte.is_some() {
                    let mid = self.push();
                    self.states[from as usize].eps.push(mid);
                    mid
                } else {
                    from
                };
                self.states[src as usize].on_byte = Some((*c, to));
            }
            Ast::Concat(items) => {
                let mut cur = from;
                for (i, item) in items.iter().enumerate() {
                    let next = if i + 1 == items.len() {
                        to
                    } else {
                        self.push()
                    };
                    self.compile(item, cur, next);
                    cur = next;
                }
                if items.is_empty() {
                    self.states[from as usize].eps.push(to);
                }
            }
            Ast::Alt(branches) => {
                for b in branches {
                    let s = self.push();
                    let e = self.push();
                    self.states[from as usize].eps.push(s);
                    self.states[e as usize].eps.push(to);
                    self.compile(b, s, e);
                }
            }
            Ast::Repeat { node, min, max } => {
                // Expand: min mandatory copies, then either (max-min)
                // optional copies or a Kleene loop.
                let mut cur = from;
                for _ in 0..*min {
                    let next = self.push();
                    self.compile(node, cur, next);
                    cur = next;
                }
                match max {
                    Some(m) => {
                        // optional tail copies, each can short-circuit to `to`
                        for _ in *min..*m {
                            self.states[cur as usize].eps.push(to);
                            let next = self.push();
                            self.compile(node, cur, next);
                            cur = next;
                        }
                        self.states[cur as usize].eps.push(to);
                    }
                    None => {
                        // Kleene star on the remainder
                        let loop_entry = self.push();
                        self.states[cur as usize].eps.push(loop_entry);
                        self.states[loop_entry as usize].eps.push(to);
                        let body_end = self.push();
                        self.compile(node, loop_entry, body_end);
                        self.states[body_end as usize].eps.push(loop_entry);
                    }
                }
            }
        }
    }

    /// Epsilon-closure of a set of states (ids, sorted, deduped).
    pub fn eps_closure(&self, set: &mut Vec<StateId>) {
        let mut stack: Vec<StateId> = set.clone();
        let mut seen = vec![false; self.states.len()];
        for &s in set.iter() {
            seen[s as usize] = true;
        }
        while let Some(s) = stack.pop() {
            for &e in &self.states[s as usize].eps {
                if !seen[e as usize] {
                    seen[e as usize] = true;
                    set.push(e);
                    stack.push(e);
                }
            }
        }
        set.sort_unstable();
        set.dedup();
    }

    /// True if any state in `set` accepts.
    pub fn any_accept(&self, set: &[StateId]) -> bool {
        set.iter().any(|&s| self.states[s as usize].accept)
    }

    /// All `(class, target)` byte transitions out of `set`.
    pub fn byte_transitions(&self, set: &[StateId]) -> Vec<(ByteClass, StateId)> {
        set.iter()
            .filter_map(|&s| self.states[s as usize].on_byte)
            .collect()
    }
}

/// Mirror an AST for reverse matching.
fn reverse_ast(ast: &Ast) -> Ast {
    match ast {
        Ast::Empty | Ast::Class(_) => ast.clone(),
        Ast::Concat(items) => Ast::Concat(items.iter().rev().map(reverse_ast).collect()),
        Ast::Alt(branches) => Ast::Alt(branches.iter().map(reverse_ast).collect()),
        Ast::Repeat { node, min, max } => Ast::Repeat {
            node: Box::new(reverse_ast(node)),
            min: *min,
            max: *max,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::parse;

    /// Direct NFA simulation, used to sanity-check construction before the
    /// DFA layer exists.
    fn nfa_matches(nfa: &Nfa, input: &[u8]) -> bool {
        let mut cur = vec![nfa.start];
        nfa.eps_closure(&mut cur);
        for &b in input {
            let mut next = Vec::new();
            for &s in &cur {
                if let Some((cls, t)) = nfa.states[s as usize].on_byte {
                    if cls.contains(b) {
                        next.push(t);
                    }
                }
            }
            nfa.eps_closure(&mut next);
            cur = next;
            if cur.is_empty() {
                return false;
            }
        }
        nfa.any_accept(&cur)
    }

    fn accepts(pat: &str, input: &str) -> bool {
        let p = parse(pat, false).unwrap();
        let nfa = Nfa::build(&p, false);
        nfa_matches(&nfa, input.as_bytes())
    }

    #[test]
    fn literals() {
        assert!(accepts("abc", "abc"));
        assert!(!accepts("abc", "abd"));
        assert!(!accepts("abc", "ab"));
        assert!(!accepts("abc", "abcd")); // anchored full-input simulation
    }

    #[test]
    fn alternation() {
        assert!(accepts("cat|dog", "cat"));
        assert!(accepts("cat|dog", "dog"));
        assert!(!accepts("cat|dog", "cow"));
    }

    #[test]
    fn star_plus_question() {
        assert!(accepts("ab*c", "ac"));
        assert!(accepts("ab*c", "abbbc"));
        assert!(accepts("ab+c", "abc"));
        assert!(!accepts("ab+c", "ac"));
        assert!(accepts("ab?c", "ac"));
        assert!(accepts("ab?c", "abc"));
        assert!(!accepts("ab?c", "abbc"));
    }

    #[test]
    fn bounded_repeats() {
        assert!(accepts("a{3}", "aaa"));
        assert!(!accepts("a{3}", "aa"));
        assert!(!accepts("a{3}", "aaaa"));
        assert!(accepts("a{2,4}", "aa"));
        assert!(accepts("a{2,4}", "aaaa"));
        assert!(!accepts("a{2,4}", "aaaaa"));
        assert!(accepts("a{2,}", "aaaaaaa"));
        assert!(!accepts("a{2,}", "a"));
    }

    #[test]
    fn nested() {
        assert!(accepts("(ab|cd)+e", "ababcde"));
        assert!(!accepts("(ab|cd)+e", "e"));
        assert!(accepts("(a|b)*", ""));
        assert!(accepts("(a|b)*", "abba"));
    }

    #[test]
    fn empty_pattern_accepts_empty() {
        assert!(accepts("", ""));
        assert!(!accepts("", "a"));
    }

    #[test]
    fn reverse_matches_reversed_input() {
        let p = parse("abc", false).unwrap();
        let rev = Nfa::build(&p, true);
        assert!(nfa_matches(&rev, b"cba"));
        assert!(!nfa_matches(&rev, b"abc"));
    }

    #[test]
    fn reverse_of_complex() {
        let p = parse(r"\d{2}-[a-z]+", false).unwrap();
        let rev = Nfa::build(&p, true);
        assert!(nfa_matches(&rev, b"zyx-42"));
        assert!(!nfa_matches(&rev, b"42-xyz"));
    }

    #[test]
    fn class_transitions_collected() {
        let p = parse("[ab][cd]", false).unwrap();
        let nfa = Nfa::build(&p, false);
        let mut start = vec![nfa.start];
        nfa.eps_closure(&mut start);
        let trans = nfa.byte_transitions(&start);
        assert_eq!(trans.len(), 1);
        assert!(trans[0].0.contains(b'a') && trans[0].0.contains(b'b'));
    }
}
