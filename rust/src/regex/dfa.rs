//! Subset-construction DFAs with dense byte-transition tables.
//!
//! The table layout is the contract with the accelerator: `table[s * 256 + b]`
//! is the next state, state [`DEAD`]` = 0` is absorbing, state
//! [`START`]` = 1` is initial, and `accept[s]` flags accepting states. The
//! Pallas kernel (`python/compile/kernels/dfa_scan.py`) consumes exactly
//! this layout, padded to the artifact's state budget — the FPGA analogy is
//! the BRAM-resident state-transition table of the paper's regex engine
//! (their ref [20]).
//!
//! Three kinds are built from one NFA:
//! * `Anchored` — matches must begin at the scan position (software
//!   matcher's inner loop);
//! * `Search` — implicit unanchored prefix: the start closure is folded
//!   into every state, so accepting states mark *match ends* anywhere in
//!   the stream. This is what streams on the accelerator.
//! * `Reverse` — anchored DFA of the mirrored pattern; scanning backwards
//!   from a match end yields the match *start* (longest = leftmost).
//!
//! Byte 0 (NUL) is the work-package document separator: every state maps
//! NUL back to [`START`] and no class ever contains it, so state never
//! leaks across document boundaries within a package.

use std::collections::HashMap;

use super::ast::Pattern;
use super::nfa::{Nfa, StateId};

/// Absorbing dead state.
pub const DEAD: u32 = 0;
/// Initial state.
pub const START: u32 = 1;

/// Construction cap — queries whose patterns blow past this are rejected at
/// compile time, mirroring the FPGA's finite state-table budget.
pub const MAX_DFA_STATES: usize = 1024;

/// Which DFA flavour to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DfaKind {
    /// Matches only from the scan start (software inner loop).
    Anchored,
    /// Match-anywhere (the table that streams on the accelerator).
    Search,
    /// Reversed pattern (match-start recovery from end reports).
    Reverse,
}

/// A dense-table DFA.
#[derive(Debug, Clone)]
pub struct Dfa {
    /// Number of states (including dead and start).
    pub num_states: u32,
    /// Row-major `num_states × 256` next-state table.
    pub table: Vec<u32>,
    /// Per-state accept flag.
    pub accept: Vec<bool>,
    /// Flavour, retained for diagnostics.
    pub kind: DfaKind,
}

/// DFA construction error (state explosion).
#[derive(Debug, Clone)]
pub struct DfaTooLarge {
    /// State count reached when the budget blew.
    pub states: usize,
}

impl std::fmt::Display for DfaTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "DFA exceeds {MAX_DFA_STATES} states ({} reached) — simplify the pattern",
            self.states
        )
    }
}

impl std::error::Error for DfaTooLarge {}

impl Dfa {
    /// Build a DFA of the requested kind for `pattern`.
    pub fn build(pattern: &Pattern, kind: DfaKind) -> Result<Dfa, DfaTooLarge> {
        let nfa = Nfa::build(pattern, kind == DfaKind::Reverse);
        let unanchored = kind == DfaKind::Search && !pattern.anchored_start;

        let mut start_set = vec![nfa.start];
        nfa.eps_closure(&mut start_set);
        let base = start_set.clone();

        // Subset construction. Sets are canonical (sorted/deduped) vectors.
        let mut ids: HashMap<Vec<StateId>, u32> = HashMap::new();
        let mut sets: Vec<Vec<StateId>> = Vec::new();

        // state 0 = dead (empty set), state 1 = start closure
        ids.insert(Vec::new(), DEAD);
        sets.push(Vec::new());
        ids.insert(start_set.clone(), START);
        sets.push(start_set);

        let mut table: Vec<u32> = Vec::new();
        let mut accept: Vec<bool> = Vec::new();
        let mut next_unprocessed = 0usize;

        while next_unprocessed < sets.len() {
            let set = sets[next_unprocessed].clone();
            next_unprocessed += 1;
            accept.push(nfa.any_accept(&set));
            let trans = nfa.byte_transitions(&set);
            let mut row = [DEAD; 256];
            // For each byte, gather targets across all class transitions.
            // Byte classes are typically few per set; iterate classes and
            // scatter into the row via per-byte target accumulation.
            let mut targets: Vec<Vec<StateId>> = vec![Vec::new(); 256];
            for (cls, t) in &trans {
                for b in cls.iter() {
                    targets[b as usize].push(*t);
                }
            }
            // Memoize per-row target-set → state id to avoid 256 closures
            // when many bytes share a target set.
            let mut row_memo: HashMap<Vec<StateId>, u32> = HashMap::new();
            for b in 0..256usize {
                if b == 0 {
                    // NUL: package separator resets the machine.
                    row[b] = START;
                    continue;
                }
                let mut tgt = std::mem::take(&mut targets[b]);
                tgt.sort_unstable();
                tgt.dedup();
                if tgt.is_empty() && !unanchored {
                    row[b] = DEAD;
                    continue;
                }
                if let Some(&id) = row_memo.get(&tgt) {
                    row[b] = id;
                    continue;
                }
                let key = tgt.clone();
                let mut closed = tgt;
                nfa.eps_closure(&mut closed);
                if unanchored {
                    // fold the start closure in: matches may begin anywhere
                    closed.extend_from_slice(&base);
                    closed.sort_unstable();
                    closed.dedup();
                }
                let id = match ids.get(&closed) {
                    Some(&id) => id,
                    None => {
                        let id = sets.len() as u32;
                        if sets.len() >= MAX_DFA_STATES {
                            return Err(DfaTooLarge { states: sets.len() });
                        }
                        ids.insert(closed.clone(), id);
                        sets.push(closed);
                        id
                    }
                };
                row_memo.insert(key, id);
                row[b] = id;
            }
            table.extend_from_slice(&row);
        }

        Ok(Dfa {
            num_states: sets.len() as u32,
            table,
            accept,
            kind,
        })
    }

    /// Next state.
    #[inline]
    pub fn step(&self, state: u32, byte: u8) -> u32 {
        self.table[state as usize * 256 + byte as usize]
    }

    /// Accept flag for `state`.
    #[inline]
    pub fn is_accept(&self, state: u32) -> bool {
        self.accept[state as usize]
    }

    /// Longest match length starting at `pos` (anchored semantics), or
    /// `None`. Empty matches are reported as `Some(0)` only if the start
    /// state accepts.
    pub fn longest_from(&self, bytes: &[u8], pos: usize) -> Option<usize> {
        debug_assert_eq!(self.kind, DfaKind::Anchored);
        let mut state = START;
        let mut best: Option<usize> = self.is_accept(state).then_some(0);
        for (i, &b) in bytes[pos..].iter().enumerate() {
            state = self.step(state, b);
            if state == DEAD {
                break;
            }
            if self.is_accept(state) {
                best = Some(i + 1);
            }
        }
        best
    }

    /// Scan the whole buffer with a Search DFA, invoking `on_end(pos)` for
    /// each position `pos` (exclusive end offset) where a match ends.
    /// This is the software mirror of the accelerator's streaming pass.
    pub fn scan_ends(&self, bytes: &[u8], mut on_end: impl FnMut(usize)) {
        debug_assert_eq!(self.kind, DfaKind::Search);
        let mut state = START;
        for (i, &b) in bytes.iter().enumerate() {
            state = self.step(state, b);
            if self.is_accept(state) {
                on_end(i + 1);
            }
        }
    }

    /// With a Reverse DFA: longest match length going backwards from
    /// byte offset `end` (exclusive). Returns the match start offset.
    pub fn longest_backward_from(&self, bytes: &[u8], end: usize) -> Option<usize> {
        self.longest_backward_bounded(bytes, end, 0)
    }

    /// Like [`Dfa::longest_backward_from`], but only starts `>= lo` count —
    /// i.e. the smallest start in `[lo, end)` of a match ending at `end`.
    /// The match-reconstruction proof in [`crate::regex::matcher`] needs
    /// this bounded form.
    pub fn longest_backward_bounded(&self, bytes: &[u8], end: usize, lo: usize) -> Option<usize> {
        debug_assert_eq!(self.kind, DfaKind::Reverse);
        let mut state = START;
        let mut best: Option<usize> = self.is_accept(state).then_some(end);
        for i in (lo..end).rev() {
            state = self.step(state, bytes[i]);
            if state == DEAD {
                break;
            }
            if self.is_accept(state) {
                best = Some(i);
            }
        }
        best
    }

    /// Approximate memory footprint of the table in bytes — used by the
    /// hardware compiler to budget machines per artifact variant (the FPGA
    /// analogue is BRAM consumption).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 4 + self.accept.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::parse;

    fn build(pat: &str, kind: DfaKind) -> Dfa {
        Dfa::build(&parse(pat, false).unwrap(), kind).unwrap()
    }

    #[test]
    fn anchored_longest() {
        let d = build("ab+", DfaKind::Anchored);
        assert_eq!(d.longest_from(b"abbbx", 0), Some(4));
        assert_eq!(d.longest_from(b"abbbx", 1), None);
        assert_eq!(d.longest_from(b"xab", 1), Some(2));
        assert_eq!(d.longest_from(b"", 0), None);
    }

    #[test]
    fn anchored_alternation_longest() {
        let d = build("a|ab|abc", DfaKind::Anchored);
        assert_eq!(d.longest_from(b"abcd", 0), Some(3));
        assert_eq!(d.longest_from(b"abd", 0), Some(2));
        assert_eq!(d.longest_from(b"ad", 0), Some(1));
    }

    #[test]
    fn search_finds_ends() {
        let d = build("ab", DfaKind::Search);
        let mut ends = Vec::new();
        d.scan_ends(b"xxabyyab", |e| ends.push(e));
        assert_eq!(ends, vec![4, 8]);
    }

    #[test]
    fn search_overlapping_ends() {
        let d = build("aa", DfaKind::Search);
        let mut ends = Vec::new();
        d.scan_ends(b"aaaa", |e| ends.push(e));
        assert_eq!(ends, vec![2, 3, 4]);
    }

    #[test]
    fn reverse_recovers_start() {
        let d = build("ab+c", DfaKind::Reverse);
        // text: "zzabbbczz", match is [2, 7)
        assert_eq!(d.longest_backward_from(b"zzabbbczz", 7), Some(2));
        assert_eq!(d.longest_backward_from(b"zzabbbczz", 6), None);
    }

    #[test]
    fn nul_resets_to_start() {
        let d = build("ab", DfaKind::Search);
        // a NUL between 'a' and 'b' must break the match
        let mut ends = Vec::new();
        d.scan_ends(b"a\0b", |e| ends.push(e));
        assert!(ends.is_empty());
        // and matching resumes fresh after the separator
        let mut ends2 = Vec::new();
        d.scan_ends(b"ab\0ab", |e| ends2.push(e));
        assert_eq!(ends2, vec![2, 5]);
    }

    #[test]
    fn dead_state_is_absorbing() {
        let d = build("abc", DfaKind::Anchored);
        let mut s = START;
        s = d.step(s, b'x');
        assert_eq!(s, DEAD);
        for b in 1..=255u8 {
            assert_eq!(d.step(DEAD, b), DEAD);
        }
        // except NUL which resets
        assert_eq!(d.step(DEAD, 0), START);
    }

    #[test]
    fn search_never_dies() {
        let d = build("abc", DfaKind::Search);
        let mut state = START;
        for &b in b"xyzzyabqqq" {
            state = d.step(state, b);
            assert_ne!(state, DEAD, "search DFA must keep the start closure live");
        }
    }

    #[test]
    fn state_count_reasonable() {
        let d = build(r"[A-Z][a-z]+", DfaKind::Search);
        assert!(d.num_states < 16, "got {}", d.num_states);
        assert_eq!(d.table.len(), d.num_states as usize * 256);
        assert_eq!(d.accept.len(), d.num_states as usize);
    }

    #[test]
    fn explosion_is_caught() {
        // (a|b)^k .{k} style patterns explode; use a{60}[ab]{60} variants —
        // bounded by parser at 64, craft something that exceeds 1024 states:
        // ".{0,60}a.{60}" has ~2^60 DFA states in theory; subset construction
        // will hit the cap quickly.
        let pat = parse(".{0,60}a.{60}", false).unwrap();
        assert!(Dfa::build(&pat, DfaKind::Search).is_err());
    }

    #[test]
    fn empty_match_from_start() {
        let d = build("a*", DfaKind::Anchored);
        assert_eq!(d.longest_from(b"bbb", 0), Some(0));
        assert_eq!(d.longest_from(b"aab", 0), Some(2));
    }

    #[test]
    fn anchored_end_handled_by_caller() {
        // '$' handling lives in the matcher (it trims candidates); the DFA
        // for the body is the same.
        let p = parse("abc$", false).unwrap();
        assert!(p.anchored_end);
        let d = Dfa::build(&p, DfaKind::Anchored).unwrap();
        assert_eq!(d.longest_from(b"abc", 0), Some(3));
    }
}
