//! Hopcroft DFA minimization.
//!
//! The FPGA's BRAM (our artifact geometries) bounds the per-machine state
//! budget; minimizing the search DFA before export lets more complex
//! patterns fit a given geometry and shrinks the table upload. Subset
//! construction output is often non-minimal (especially for unanchored
//! search DFAs where the start closure is folded into every state).
//!
//! The dead state (0) and start-state id (1) conventions of
//! [`crate::regex::dfa`] are preserved by remapping after partitioning.

use super::dfa::{Dfa, DEAD, START};

/// Minimize `dfa`, preserving the state-id conventions (0 = dead,
/// 1 = start). Returns a DFA accepting exactly the same language with the
/// minimal number of states.
pub fn minimize(dfa: &Dfa) -> Dfa {
    let n = dfa.num_states as usize;
    if n <= 2 {
        return dfa.clone();
    }

    // --- Hopcroft partition refinement ---
    // initial partition: accepting vs non-accepting (dead kept separate so
    // its absorbing identity survives; it is non-accepting anyway)
    let mut block_of: Vec<u32> = (0..n)
        .map(|s| if dfa.accept[s] { 1 } else { 0 })
        .collect();
    let mut num_blocks = 2u32;
    // handle degenerate cases: all accepting or none
    if !dfa.accept.iter().any(|&a| a) || dfa.accept.iter().all(|&a| a) {
        // single block — still refine below (transitions differ)
        for b in block_of.iter_mut() {
            *b = 0;
        }
        num_blocks = 1;
    }

    // iterative refinement to fixpoint (simple Moore algorithm — O(n²·Σ)
    // worst case, fine for our ≤1024-state tables; Hopcroft's worklist
    // optimization is unnecessary at this scale)
    loop {
        let mut changed = false;
        // signature of a state: (its block, blocks of its 256 successors)
        use std::collections::HashMap;
        let mut sig_to_new: HashMap<(u32, Vec<u32>), u32> = HashMap::new();
        let mut new_block_of = vec![0u32; n];
        let mut next_block = 0u32;
        for s in 0..n {
            let sig: Vec<u32> = (0..256)
                .map(|b| block_of[dfa.table[s * 256 + b] as usize])
                .collect();
            let key = (block_of[s], sig);
            let id = *sig_to_new.entry(key).or_insert_with(|| {
                let id = next_block;
                next_block += 1;
                id
            });
            new_block_of[s] = id;
        }
        if next_block != num_blocks {
            changed = true;
        }
        block_of = new_block_of;
        num_blocks = next_block;
        if !changed {
            break;
        }
    }

    // --- rebuild with conventions: dead block -> 0, start block -> 1 ---
    let dead_block = block_of[DEAD as usize];
    let start_block = block_of[START as usize];
    let mut remap: Vec<Option<u32>> = vec![None; num_blocks as usize];
    remap[dead_block as usize] = Some(DEAD);
    let mut next_id = if start_block == dead_block {
        // pathological (empty language): start ≡ dead; keep two states to
        // satisfy the layout conventions
        1
    } else {
        remap[start_block as usize] = Some(START);
        2
    };
    for s in 0..n {
        let b = block_of[s] as usize;
        if remap[b].is_none() {
            remap[b] = Some(next_id);
            next_id += 1;
        }
    }
    let new_n = next_id.max(2) as usize;

    let mut table = vec![DEAD; new_n * 256];
    let mut accept = vec![false; new_n];
    // NUL resets to START everywhere, even in padding rows
    for row in table.chunks_mut(256) {
        row[0] = START;
    }
    for s in 0..n {
        let ns = remap[block_of[s] as usize].unwrap() as usize;
        accept[ns] = dfa.accept[s];
        for b in 0..256 {
            let t = dfa.table[s * 256 + b] as usize;
            table[ns * 256 + b] = remap[block_of[t] as usize].unwrap();
        }
    }

    Dfa {
        num_states: new_n as u32,
        table,
        accept,
        kind: dfa.kind,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::parse;
    use crate::regex::dfa::DfaKind;

    fn build(pat: &str, kind: DfaKind) -> Dfa {
        Dfa::build(&parse(pat, false).unwrap(), kind).unwrap()
    }

    /// Language equivalence check by scanning random and structured text.
    fn same_ends(a: &Dfa, b: &Dfa, text: &[u8]) -> bool {
        let mut ea = Vec::new();
        let mut eb = Vec::new();
        a.scan_ends(text, |e| ea.push(e));
        b.scan_ends(text, |e| eb.push(e));
        ea == eb
    }

    #[test]
    fn minimization_preserves_language() {
        use crate::util::Prng;
        let mut rng = Prng::new(42);
        for pat in [
            "abc",
            "a+b*c?",
            "(ab|ba)+",
            r"[A-Z][a-z]+ [A-Z][a-z]+",
            r"\d{3}-\d{4}",
            r"(a|b)(a|b)(a|b)",
            r"x|xy|xyz",
        ] {
            let d = build(pat, DfaKind::Search);
            let m = minimize(&d);
            assert!(m.num_states <= d.num_states, "{pat}");
            for _ in 0..100 {
                let len = rng.below(80);
                let text = rng.string_over(b"abcxyzABC dXY019-", len.max(1));
                assert!(
                    same_ends(&d, &m, text.as_bytes()),
                    "language changed for /{pat}/ on {text:?}"
                );
            }
        }
    }

    #[test]
    fn minimization_shrinks_redundant_dfas() {
        // x|xy|xyz: subset construction makes distinct accept states that
        // minimization can merge
        let d = build("abc|abd|abe", DfaKind::Search);
        let m = minimize(&d);
        assert!(m.num_states < d.num_states, "{} vs {}", m.num_states, d.num_states);
    }

    #[test]
    fn conventions_preserved() {
        // NUL resets everywhere; state 0 non-accepting (it is only truly
        // absorbing in ANCHORED DFAs — search DFAs fold the start closure
        // into every row, including the unreachable state 0).
        let m = minimize(&build("ab", DfaKind::Search));
        for s in 0..m.num_states {
            assert_eq!(m.step(s, 0), START);
        }
        assert!(!m.is_accept(DEAD));

        let a = minimize(&build("ab", DfaKind::Anchored));
        for b in 1..=255u8 {
            assert_eq!(a.step(DEAD, b), DEAD, "anchored dead must absorb");
        }
        assert_eq!(a.step(DEAD, 0), START);
    }

    #[test]
    fn anchored_and_reverse_also_minimize() {
        for kind in [DfaKind::Anchored, DfaKind::Reverse] {
            let d = build("(ab|cd){1,3}", kind);
            let m = minimize(&d);
            assert!(m.num_states <= d.num_states);
            // anchored longest semantics preserved
            if kind == DfaKind::Anchored {
                for text in [&b"ababab"[..], b"cdab", b"x", b""] {
                    assert_eq!(d.longest_from(text, 0), m.longest_from(text, 0));
                }
            }
        }
    }

    #[test]
    fn tiny_dfas_pass_through() {
        let d = build("", DfaKind::Search);
        let m = minimize(&d);
        assert!(m.num_states >= 2);
    }
}
