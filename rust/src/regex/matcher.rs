//! Match semantics: the software all-matches scan and the hardware
//! candidate reconstruction, which must agree.
//!
//! **Contract.** `find_all` returns non-overlapping matches, chosen
//! leftmost-first and longest-at-each-start, restarting after each match
//! end — SystemT's regex-extraction semantics. `from_hw_ends` reconstructs
//! the same set from a Search-DFA end-position stream (what the
//! accelerator reports) using the Reverse DFA for start recovery and a
//! greedy left-to-right selection. The equivalence of the two paths is
//! enforced by tests here and revalidated per query pattern at
//! hardware-compile time ([`crate::hwcompiler`]).

use crate::text::Span;

use super::ast::{ParseError, Pattern};
use super::dfa::{Dfa, DfaKind, DfaTooLarge};

/// One regex match.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Match {
    /// Matched byte range.
    pub span: Span,
}

/// A pattern compiled to all three DFAs.
#[derive(Debug, Clone)]
pub struct CompiledRegex {
    /// The parsed source pattern.
    pub pattern: Pattern,
    /// Anchored DFA — software scan inner loop.
    pub anchored: Dfa,
    /// Search DFA — the table that streams on the accelerator.
    pub search: Dfa,
    /// Reverse DFA — match-start recovery from hardware end reports.
    pub reverse: Dfa,
}

impl CompiledRegex {
    /// Compile a parsed pattern (builds three DFAs).
    pub fn from_pattern(pattern: Pattern) -> Result<Self, ParseError> {
        let lift = |e: DfaTooLarge| ParseError {
            pos: 0,
            msg: e.to_string(),
        };
        // Hopcroft-minimized tables: smaller uploads and more patterns fit
        // the artifact state budgets (the FPGA's BRAM, in paper terms).
        let anchored =
            super::minimize::minimize(&Dfa::build(&pattern, DfaKind::Anchored).map_err(lift)?);
        let search =
            super::minimize::minimize(&Dfa::build(&pattern, DfaKind::Search).map_err(lift)?);
        let reverse =
            super::minimize::minimize(&Dfa::build(&pattern, DfaKind::Reverse).map_err(lift)?);
        Ok(CompiledRegex {
            pattern,
            anchored,
            search,
            reverse,
        })
    }

    /// Software semantics: scan left to right; at each position take the
    /// longest match, emit it, and continue from its end (non-overlapping).
    /// Empty matches are skipped (SystemT never emits zero-length spans).
    pub fn find_all(&self, text: &str) -> Vec<Match> {
        let mut out = Vec::new();
        self.find_all_each(text, |span| out.push(Match { span }));
        out
    }

    /// [`CompiledRegex::find_all`] appending spans to `out` — the columnar
    /// extraction path writes matches straight into an arena-backed span
    /// column, with no per-match `Match`/tuple values in between.
    pub fn find_all_spans_into(&self, text: &str, out: &mut Vec<Span>) {
        self.find_all_each(text, |span| out.push(span));
    }

    /// The scan core shared by both emit shapes.
    fn find_all_each(&self, text: &str, mut emit: impl FnMut(Span)) {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let start_bound = if self.pattern.anchored_start { 1 } else { bytes.len() + 1 };
        while pos < bytes.len() && pos < start_bound {
            match self.anchored.longest_from(bytes, pos) {
                Some(len) if len > 0 => {
                    let end = pos + len;
                    if !self.pattern.anchored_end || end == bytes.len() {
                        emit(Span::new(pos as u32, end as u32));
                        pos = end;
                        continue;
                    }
                    // end-anchored and this end isn't the doc end: try to
                    // find a shorter/longer fit — with our subset (top-level
                    // `$` only), only the doc-end match counts; advance.
                    pos += 1;
                }
                _ => pos += 1,
            }
        }
    }

    /// Hardware-path reconstruction. `ends` are exclusive end offsets where
    /// the Search DFA accepted (as streamed back by the accelerator), in
    /// increasing order. Reproduces [`CompiledRegex::find_all`] exactly.
    ///
    /// **Why this is correct.** The software semantics picks, from cursor
    /// `c`, the match with the smallest start `s ≥ c` (longest end at that
    /// start), then sets `c` to its end. For each reported end `e`, let
    /// `s(e, c)` be the smallest start in `[c, e)` of a match ending at `e`
    /// (computed by the Reverse DFA bounded backward scan). Let `s*` be the
    /// software pick's start and `E` its end. Then (i) the candidate
    /// `(s(E,c), E)` has `s(E,c) = s*` — a match `(s', E)` with
    /// `c ≤ s' < s*` would contradict minimality of `s*`, and `(s*, E)`
    /// itself bounds `s(E,c) ≤ s*`; and (ii) no candidate has a smaller
    /// start (its start is also a match start `≥ c`) and none with start
    /// `s*` has a larger end (that would contradict `E` being the longest
    /// end from `s*`). So "min start, then max end" over per-end bounded
    /// candidates equals the software pick, round by round.
    pub fn from_hw_ends(&self, text: &str, ends: &[usize]) -> Vec<Match> {
        let mut out = Vec::new();
        self.from_hw_ends_each(text, ends, |span| out.push(Match { span }));
        out
    }

    /// [`CompiledRegex::from_hw_ends`] appending spans to `out` — the
    /// accelerator post-stage reconstructs straight into an arena-backed
    /// span column.
    pub fn from_hw_ends_spans_into(&self, text: &str, ends: &[usize], out: &mut Vec<Span>) {
        self.from_hw_ends_each(text, ends, |span| out.push(span));
    }

    fn from_hw_ends_each(&self, text: &str, ends: &[usize], mut emit: impl FnMut(Span)) {
        let bytes = text.as_bytes();
        let ends: Vec<usize> = ends
            .iter()
            .copied()
            .filter(|&e| !self.pattern.anchored_end || e == bytes.len())
            .collect();
        let mut cursor = 0usize;
        let mut lo = 0usize; // index of first end still usable
        loop {
            let mut best: Option<(usize, usize)> = None;
            for (idx, &e) in ends.iter().enumerate().skip(lo) {
                if e <= cursor {
                    lo = idx + 1;
                    continue;
                }
                if let Some(s) = self.reverse.longest_backward_bounded(bytes, e, cursor) {
                    if self.pattern.anchored_start && s != 0 {
                        continue;
                    }
                    if e <= s {
                        continue; // empty match — never emitted
                    }
                    best = match best {
                        None => Some((s, e)),
                        Some((bs, be)) if s < bs || (s == bs && e > be) => Some((s, e)),
                        b => b,
                    };
                }
            }
            match best {
                Some((s, e)) => {
                    emit(Span::new(s as u32, e as u32));
                    cursor = e;
                }
                None => break,
            }
        }
    }

    /// Run the Search DFA in software and reconstruct — this is the oracle
    /// the hardware path is tested against end-to-end, and doubles as a
    /// fallback when no accelerator is configured.
    pub fn find_all_via_ends(&self, text: &str) -> Vec<Match> {
        let mut ends = Vec::new();
        self.search.scan_ends(text.as_bytes(), |e| ends.push(e));
        self.from_hw_ends(text, &ends)
    }

    /// Verify on `text` that the hardware path equals the software path.
    /// The hardware compiler calls this on generated sample text before
    /// accepting a pattern for offload.
    pub fn hw_semantics_agree(&self, text: &str) -> bool {
        self.find_all(text) == self.find_all_via_ends(text)
    }
}

#[cfg(test)]
mod tests {
    use crate::regex::compile;

    fn spans(pat: &str, text: &str) -> Vec<(u32, u32)> {
        compile(pat, false)
            .unwrap()
            .find_all(text)
            .iter()
            .map(|m| (m.span.begin, m.span.end))
            .collect()
    }

    #[test]
    fn simple_all_matches() {
        assert_eq!(spans("ab", "abxxab"), vec![(0, 2), (4, 6)]);
    }

    #[test]
    fn longest_at_start() {
        assert_eq!(spans("a+", "aaab"), vec![(0, 3)]);
        assert_eq!(spans("a|ab", "ab"), vec![(0, 2)]);
    }

    #[test]
    fn non_overlapping_restart() {
        assert_eq!(spans("aa", "aaaa"), vec![(0, 2), (2, 4)]);
        assert_eq!(spans("aa", "aaa"), vec![(0, 2)]);
    }

    #[test]
    fn no_empty_matches() {
        assert_eq!(spans("a*", "bbb"), Vec::<(u32, u32)>::new());
        assert_eq!(spans("a*", "bab"), vec![(1, 2)]);
    }

    #[test]
    fn anchored_start() {
        assert_eq!(spans("^ab", "abab"), vec![(0, 2)]);
        assert_eq!(spans("^ab", "xab"), Vec::<(u32, u32)>::new());
    }

    #[test]
    fn anchored_end() {
        assert_eq!(spans("ab$", "abab"), vec![(2, 4)]);
        assert_eq!(spans("ab$", "abx"), Vec::<(u32, u32)>::new());
    }

    #[test]
    fn realistic_phone() {
        let t = "Call 555-1234, or (408) 555-9876 x22.";
        assert_eq!(
            spans(r"(\(\d{3}\) )?\d{3}-\d{4}", t),
            vec![(5, 13), (18, 32)]
        );
    }

    #[test]
    fn hw_path_equals_sw_path_basic() {
        for (pat, text) in [
            ("ab", "abxxabab"),
            ("a+", "aaabaaa"),
            ("aa", "aaaaa"),
            ("a|ab", "ababab"),
            ("aa|ab", "aab"),
            (r"\d{3}-\d{4}", "x 555-1234 555-99999"),
            (r"[A-Z][a-z]+", "Alice met Bob at IBM Research"),
            ("(ab|ba)+", "abbaabx"),
        ] {
            let re = compile(pat, false).unwrap();
            assert_eq!(
                re.find_all(text),
                re.find_all_via_ends(text),
                "divergence for /{pat}/ on {text:?}"
            );
        }
    }

    #[test]
    fn hw_path_equals_sw_path_property() {
        use crate::util::{prop, Prng};
        // Patterns chosen to cover classes, repeats, alternation — the
        // shapes real extraction rules use.
        let pats = [
            r"[ab]+",
            r"a[ab]{2}b",
            r"ab|ba",
            r"a+b+",
            r"(a|b)(a|b)",
            r"\d+",
            r"[a-c]{2,4}",
        ];
        for pat in pats {
            let re = compile(pat, false).unwrap();
            prop::check(
                1234,
                300,
                |r: &mut Prng| {
                    let len = r.below(40).max(1);
                    r.string_over(b"abc d1", len)
                },
                |text| re.find_all(text) == re.find_all_via_ends(text),
            );
        }
    }

    #[test]
    fn spans_into_variants_agree_with_vec_forms() {
        let re = compile(r"[A-Z][a-z]+", false).unwrap();
        let text = "Alice met Bob at IBM Research";
        let mut direct = Vec::new();
        re.find_all_spans_into(text, &mut direct);
        assert_eq!(
            direct,
            re.find_all(text).iter().map(|m| m.span).collect::<Vec<_>>()
        );
        let mut ends = Vec::new();
        re.search.scan_ends(text.as_bytes(), |e| ends.push(e));
        let mut hw = Vec::new();
        re.from_hw_ends_spans_into(text, &ends, &mut hw);
        assert_eq!(
            hw,
            re.from_hw_ends(text, &ends)
                .iter()
                .map(|m| m.span)
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn agree_helper() {
        let re = compile(r"[A-Z][a-z]+", false).unwrap();
        assert!(re.hw_semantics_agree("Alice and Bob went to Zurich."));
    }

    #[test]
    fn case_insensitive_end_to_end() {
        let re = compile("ibm research", true).unwrap();
        let t = "IBM Research and ibm research";
        assert_eq!(re.find_all(t).len(), 2);
        assert_eq!(re.find_all(t), re.find_all_via_ends(t));
    }

    #[test]
    fn matches_across_nul_are_broken() {
        // NUL simulates the package separator: no match may cross it.
        let re = compile("ab", false).unwrap();
        let text_with_sep = "a\0b";
        assert_eq!(re.find_all_via_ends(text_with_sep).len(), 0);
    }
}

/// Differential tests against the third-party `regex` crate (test oracle
/// only — the engine itself never uses it). Gated behind the
/// `oracle-tests` feature because the offline build carries no external
/// dev-dependencies; see Cargo.toml for how to enable.
#[cfg(all(test, feature = "oracle-tests"))]
mod oracle_tests {
    use crate::regex::compile;
    // (no items from super needed — the oracle is the vendored regex crate)

    /// Oracle semantics: regex crate find_iter is leftmost-first (not
    /// longest-alternation), so restrict to patterns where the two agree
    /// (no ambiguous alternations).
    fn check_against_oracle(pat: &str, texts: &[&str]) {
        let mine = compile(pat, false).unwrap();
        let oracle = regex::Regex::new(pat).unwrap();
        for t in texts {
            let got: Vec<(usize, usize)> = mine
                .find_all(t)
                .iter()
                .map(|m| (m.span.begin as usize, m.span.end as usize))
                .collect();
            let want: Vec<(usize, usize)> =
                oracle.find_iter(t).map(|m| (m.start(), m.end())).collect();
            assert_eq!(got, want, "pattern /{pat}/ on {t:?}");
        }
    }

    #[test]
    fn oracle_simple() {
        check_against_oracle("ab", &["", "ab", "abab", "xxabxx", "aab"]);
        check_against_oracle("a+", &["aaa", "baaab", "ab a ab"]);
        check_against_oracle(r"\d{3}-\d{4}", &["555-1234", "x555-12345y", "12-3456"]);
        check_against_oracle(r"[A-Z][a-z]+", &["Alice met Bob", "IBM", "aA bB Cc"]);
    }

    #[test]
    fn oracle_repeats_and_classes() {
        check_against_oracle("a{2,4}", &["a", "aa", "aaaaa", "aaaaaaaa"]);
        check_against_oracle(r"[abc]+d", &["abcd", "dd", "cabdab"]);
        check_against_oracle(r"x[0-9]*y", &["xy", "x123y", "x12z"]);
    }

    #[test]
    fn oracle_random_texts() {
        use crate::util::Prng;
        let mut rng = Prng::new(99);
        let pats = [r"a+b", r"[ab]c", r"ab*c", r"(?:ab){1,3}", r"\w+@\w+"];
        for pat in pats {
            let mine = compile(pat, false).unwrap();
            let oracle = regex::Regex::new(pat).unwrap();
            for _ in 0..200 {
                let len = rng.below(60).max(1);
                let t = rng.string_over(b"abc@x ", len);
                let got: Vec<(usize, usize)> = mine
                    .find_all(&t)
                    .iter()
                    .map(|m| (m.span.begin as usize, m.span.end as usize))
                    .collect();
                let want: Vec<(usize, usize)> =
                    oracle.find_iter(&t).map(|m| (m.start(), m.end())).collect();
                assert_eq!(got, want, "pattern /{pat}/ on {t:?}");
            }
        }
    }
}
