//! Pattern syntax, byte classes, and the recursive-descent parser.
//!
//! Supported syntax (a practical subset sufficient for information
//! extraction rules — phone numbers, emails, capitalized words, amounts):
//!
//! ```text
//! pattern   := alt
//! alt       := concat ('|' concat)*
//! concat    := repeat*
//! repeat    := atom ('*' | '+' | '?' | '{m}' | '{m,}' | '{m,n}')?
//! atom      := literal | '.' | class | '(' alt ')' | '(?:' alt ')' | escape
//! class     := '[' '^'? item+ ']'      item := byte | byte '-' byte | escape-class
//! escape    := '\d' '\D' '\w' '\W' '\s' '\S' | '\' punct
//! anchors   := '^' at pattern start, '$' at pattern end only
//! ```
//!
//! Patterns are byte-oriented (ASCII); the corpus generator never emits
//! non-ASCII, matching the paper's "sequence of ASCII characters".

use std::fmt;

/// A set of bytes, stored as a 256-bit bitmap.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct ByteClass(pub [u64; 4]);

impl ByteClass {
    /// The empty class.
    pub fn empty() -> Self {
        ByteClass([0; 4])
    }

    /// Class containing a single byte.
    pub fn single(b: u8) -> Self {
        let mut c = Self::empty();
        c.insert(b);
        c
    }

    /// Class containing every byte except NUL (NUL is reserved as the
    /// accelerator's work-package separator, so `.` never matches it).
    pub fn dot() -> Self {
        let mut c = ByteClass([!0; 4]);
        c.remove(0);
        // `.` also conventionally excludes newline
        c.remove(b'\n');
        c
    }

    /// Add a byte.
    pub fn insert(&mut self, b: u8) {
        self.0[(b >> 6) as usize] |= 1u64 << (b & 63);
    }

    /// Remove a byte.
    pub fn remove(&mut self, b: u8) {
        self.0[(b >> 6) as usize] &= !(1u64 << (b & 63));
    }

    /// Add an inclusive byte range.
    pub fn insert_range(&mut self, lo: u8, hi: u8) {
        for b in lo..=hi {
            self.insert(b);
        }
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, b: u8) -> bool {
        self.0[(b >> 6) as usize] & (1u64 << (b & 63)) != 0
    }

    /// Complement (NUL stays excluded — see [`ByteClass::dot`]).
    pub fn negate(&self) -> Self {
        let mut c = ByteClass([!self.0[0], !self.0[1], !self.0[2], !self.0[3]]);
        c.remove(0);
        c
    }

    /// Union.
    pub fn union(&self, other: &Self) -> Self {
        ByteClass([
            self.0[0] | other.0[0],
            self.0[1] | other.0[1],
            self.0[2] | other.0[2],
            self.0[3] | other.0[3],
        ])
    }

    /// ASCII case-fold: for each letter present, add the other case.
    pub fn case_fold(&self) -> Self {
        let mut c = *self;
        for b in b'a'..=b'z' {
            if self.contains(b) {
                c.insert(b - 32);
            }
        }
        for b in b'A'..=b'Z' {
            if self.contains(b) {
                c.insert(b + 32);
            }
        }
        c
    }

    /// `\d`
    pub fn digit() -> Self {
        let mut c = Self::empty();
        c.insert_range(b'0', b'9');
        c
    }

    /// `\w`
    pub fn word() -> Self {
        let mut c = Self::digit();
        c.insert_range(b'a', b'z');
        c.insert_range(b'A', b'Z');
        c.insert(b'_');
        c
    }

    /// `\s`
    pub fn space() -> Self {
        let mut c = Self::empty();
        for b in [b' ', b'\t', b'\n', b'\r', 0x0b, 0x0c] {
            c.insert(b);
        }
        c
    }

    /// Iterate over member bytes.
    pub fn iter(&self) -> impl Iterator<Item = u8> + '_ {
        (0u16..256).filter_map(move |b| {
            let b = b as u8;
            self.contains(b).then_some(b)
        })
    }
}

impl fmt::Debug for ByteClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ByteClass[")?;
        let mut n = 0;
        for b in self.iter() {
            if n > 8 {
                write!(f, "…")?;
                break;
            }
            if b.is_ascii_graphic() {
                write!(f, "{}", b as char)?;
            } else {
                write!(f, "\\x{b:02x}")?;
            }
            n += 1;
        }
        write!(f, "]")
    }
}

/// Abstract syntax tree of a pattern body (anchors live on [`Pattern`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Ast {
    /// Matches the empty string.
    Empty,
    /// One byte from the class.
    Class(ByteClass),
    /// Sequence.
    Concat(Vec<Ast>),
    /// Alternation.
    Alt(Vec<Ast>),
    /// `node{min, max}`; `max == None` means unbounded.
    Repeat {
        node: Box<Ast>,
        min: u32,
        max: Option<u32>,
    },
}

/// A parsed pattern: body plus top-level anchors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pattern {
    /// The pattern body.
    pub ast: Ast,
    /// Pattern begins with `^`.
    pub anchored_start: bool,
    /// Pattern ends with `$`.
    pub anchored_end: bool,
    /// Original source, retained for diagnostics and AOG dumps.
    pub source: String,
}

impl Pattern {
    /// A sample of bytes the pattern can consume (up to a few per class),
    /// plus common separators — used by the hardware compiler to generate
    /// adversarial validation text for the SW/HW semantics check.
    pub fn alphabet_sample(&self) -> Vec<u8> {
        fn walk(ast: &Ast, out: &mut Vec<u8>) {
            match ast {
                Ast::Empty => {}
                Ast::Class(c) => {
                    for (k, b) in c.iter().enumerate() {
                        if k >= 6 {
                            break;
                        }
                        out.push(b);
                    }
                }
                Ast::Concat(v) | Ast::Alt(v) => {
                    for a in v {
                        walk(a, out);
                    }
                }
                Ast::Repeat { node, .. } => walk(node, out),
            }
        }
        let mut out = Vec::new();
        walk(&self.ast, &mut out);
        out.extend_from_slice(b" .,x1");
        out.sort_unstable();
        out.dedup();
        out.retain(|&b| b != 0); // NUL is the package separator
        out
    }
}

/// Parse failure with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset in the pattern source.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Maximum expansion for bounded repeats, to keep NFAs small.
const MAX_BOUNDED_REPEAT: u32 = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    fold: bool,
}

/// Parse `pattern`; `case_insensitive` folds ASCII case into classes.
pub fn parse(pattern: &str, case_insensitive: bool) -> Result<Pattern, ParseError> {
    let mut p = Parser {
        bytes: pattern.as_bytes(),
        pos: 0,
        fold: case_insensitive,
    };
    let anchored_start = p.eat(b'^');
    let ast = p.parse_alt()?;
    // `$` must be the final byte if present
    let anchored_end = p.eat(b'$');
    if p.pos != p.bytes.len() {
        return Err(p.err("unexpected trailing input (unbalanced ')'?)"));
    }
    Ok(Pattern {
        ast,
        anchored_start,
        anchored_end,
        source: pattern.to_string(),
    })
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn parse_alt(&mut self) -> Result<Ast, ParseError> {
        let mut branches = vec![self.parse_concat()?];
        while self.eat(b'|') {
            branches.push(self.parse_concat()?);
        }
        Ok(if branches.len() == 1 {
            branches.pop().unwrap()
        } else {
            Ast::Alt(branches)
        })
    }

    fn parse_concat(&mut self) -> Result<Ast, ParseError> {
        let mut items = Vec::new();
        loop {
            match self.peek() {
                None | Some(b'|') | Some(b')') => break,
                Some(b'$') if self.pos + 1 == self.bytes.len() => break,
                _ => items.push(self.parse_repeat()?),
            }
        }
        Ok(match items.len() {
            0 => Ast::Empty,
            1 => items.pop().unwrap(),
            _ => Ast::Concat(items),
        })
    }

    fn parse_repeat(&mut self) -> Result<Ast, ParseError> {
        let atom = self.parse_atom()?;
        let (min, max) = match self.peek() {
            Some(b'*') => {
                self.pos += 1;
                (0, None)
            }
            Some(b'+') => {
                self.pos += 1;
                (1, None)
            }
            Some(b'?') => {
                self.pos += 1;
                (0, Some(1))
            }
            Some(b'{') => {
                self.pos += 1;
                let min = self.parse_number()?;
                let max = if self.eat(b',') {
                    if self.peek() == Some(b'}') {
                        None
                    } else {
                        Some(self.parse_number()?)
                    }
                } else {
                    Some(min)
                };
                if !self.eat(b'}') {
                    return Err(self.err("expected '}' in repetition"));
                }
                if let Some(m) = max {
                    if m < min {
                        return Err(self.err("repetition max < min"));
                    }
                    if m > MAX_BOUNDED_REPEAT {
                        return Err(self.err("bounded repetition too large (max 64)"));
                    }
                } else if min > MAX_BOUNDED_REPEAT {
                    return Err(self.err("bounded repetition too large (max 64)"));
                }
                (min, max)
            }
            _ => return Ok(atom),
        };
        // reject double quantifiers like a**
        if matches!(self.peek(), Some(b'*') | Some(b'+') | Some(b'?')) {
            return Err(self.err("nested quantifier"));
        }
        Ok(Ast::Repeat {
            node: Box::new(atom),
            min,
            max,
        })
    }

    fn parse_number(&mut self) -> Result<u32, ParseError> {
        let start = self.pos;
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected number"));
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .parse()
            .map_err(|_| self.err("number too large"))
    }

    fn parse_atom(&mut self) -> Result<Ast, ParseError> {
        match self.bump() {
            None => Err(self.err("unexpected end of pattern")),
            Some(b'(') => {
                // (?: ... ) and ( ... ) both mean grouping — we do not
                // support capture semantics (SystemT extracts whole-match
                // spans; group extraction is future work).
                if self.peek() == Some(b'?') {
                    self.pos += 1;
                    if !self.eat(b':') {
                        return Err(self.err("only (?: ) groups are supported"));
                    }
                }
                let inner = self.parse_alt()?;
                if !self.eat(b')') {
                    return Err(self.err("expected ')'"));
                }
                Ok(inner)
            }
            Some(b'[') => self.parse_class().map(Ast::Class),
            Some(b'.') => Ok(Ast::Class(ByteClass::dot())),
            Some(b'\\') => self.parse_escape().map(Ast::Class),
            Some(b @ (b'*' | b'+' | b'?')) => {
                self.pos -= 1;
                let _ = b;
                Err(self.err("quantifier with nothing to repeat"))
            }
            Some(b'^') => Err(self.err("'^' only supported at pattern start")),
            Some(b'$') => Err(self.err("'$' only supported at pattern end")),
            Some(b) => {
                let cls = ByteClass::single(b);
                Ok(Ast::Class(if self.fold { cls.case_fold() } else { cls }))
            }
        }
    }

    fn parse_escape(&mut self) -> Result<ByteClass, ParseError> {
        match self.bump() {
            None => Err(self.err("dangling '\\'")),
            Some(b'd') => Ok(ByteClass::digit()),
            Some(b'D') => Ok(ByteClass::digit().negate()),
            Some(b'w') => Ok(ByteClass::word()),
            Some(b'W') => Ok(ByteClass::word().negate()),
            Some(b's') => Ok(ByteClass::space()),
            Some(b'S') => Ok(ByteClass::space().negate()),
            Some(b'n') => Ok(ByteClass::single(b'\n')),
            Some(b't') => Ok(ByteClass::single(b'\t')),
            Some(b'r') => Ok(ByteClass::single(b'\r')),
            Some(b) if b.is_ascii_punctuation() => Ok(ByteClass::single(b)),
            Some(_) => Err(self.err("unsupported escape")),
        }
    }

    fn parse_class(&mut self) -> Result<ByteClass, ParseError> {
        let negated = self.eat(b'^');
        let mut cls = ByteClass::empty();
        let mut first = true;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated character class")),
                Some(b']') if !first => {
                    self.pos += 1;
                    break;
                }
                _ => {}
            }
            first = false;
            let lo = match self.bump().unwrap() {
                b'\\' => {
                    let sub = self.parse_escape()?;
                    // escape-classes can't form ranges
                    cls = cls.union(&sub);
                    continue;
                }
                b => b,
            };
            if self.peek() == Some(b'-')
                && self.bytes.get(self.pos + 1).is_some_and(|&b| b != b']')
            {
                self.pos += 1; // '-'
                let hi = match self.bump().unwrap() {
                    b'\\' => return Err(self.err("escape not allowed as range end")),
                    b => b,
                };
                if hi < lo {
                    return Err(self.err("invalid range (hi < lo)"));
                }
                cls.insert_range(lo, hi);
            } else {
                cls.insert(lo);
            }
        }
        if self.fold {
            cls = cls.case_fold();
        }
        Ok(if negated { cls.negate() } else { cls })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_concat() {
        let p = parse("abc", false).unwrap();
        match p.ast {
            Ast::Concat(v) => assert_eq!(v.len(), 3),
            other => panic!("expected concat, got {other:?}"),
        }
    }

    #[test]
    fn alternation() {
        let p = parse("a|b|c", false).unwrap();
        match p.ast {
            Ast::Alt(v) => assert_eq!(v.len(), 3),
            other => panic!("expected alt, got {other:?}"),
        }
    }

    #[test]
    fn quantifiers() {
        for (pat, min, max) in [
            ("a*", 0, None),
            ("a+", 1, None),
            ("a?", 0, Some(1)),
            ("a{3}", 3, Some(3)),
            ("a{2,}", 2, None),
            ("a{2,5}", 2, Some(5)),
        ] {
            let p = parse(pat, false).unwrap();
            match p.ast {
                Ast::Repeat { min: m, max: x, .. } => {
                    assert_eq!((m, x), (min, max), "pattern {pat}");
                }
                other => panic!("expected repeat for {pat}, got {other:?}"),
            }
        }
    }

    #[test]
    fn anchors() {
        let p = parse("^ab$", false).unwrap();
        assert!(p.anchored_start && p.anchored_end);
        let p = parse("ab", false).unwrap();
        assert!(!p.anchored_start && !p.anchored_end);
    }

    #[test]
    fn classes() {
        let p = parse("[a-cx]", false).unwrap();
        if let Ast::Class(c) = p.ast {
            assert!(c.contains(b'a') && c.contains(b'b') && c.contains(b'c'));
            assert!(c.contains(b'x'));
            assert!(!c.contains(b'd'));
        } else {
            panic!("expected class");
        }
    }

    #[test]
    fn negated_class_excludes_nul() {
        let p = parse("[^a]", false).unwrap();
        if let Ast::Class(c) = p.ast {
            assert!(!c.contains(b'a'));
            assert!(c.contains(b'b'));
            assert!(!c.contains(0), "NUL is the package separator");
        } else {
            panic!("expected class");
        }
    }

    #[test]
    fn class_with_escapes_and_literal_dash() {
        let p = parse(r"[\d\-x-]", false).unwrap();
        if let Ast::Class(c) = p.ast {
            assert!(c.contains(b'5') && c.contains(b'-') && c.contains(b'x'));
            assert!(!c.contains(b'a'));
        } else {
            panic!("expected class");
        }
    }

    #[test]
    fn dot_excludes_newline_and_nul() {
        let c = ByteClass::dot();
        assert!(c.contains(b'a') && c.contains(b' '));
        assert!(!c.contains(b'\n') && !c.contains(0));
    }

    #[test]
    fn escapes() {
        for (pat, yes, no) in [
            (r"\d", b'7', b'a'),
            (r"\w", b'_', b'-'),
            (r"\s", b' ', b'x'),
            (r"\.", b'.', b'a'),
        ] {
            let p = parse(pat, false).unwrap();
            if let Ast::Class(c) = p.ast {
                assert!(c.contains(yes), "{pat} should match {yes}");
                assert!(!c.contains(no), "{pat} should not match {no}");
            } else {
                panic!("expected class for {pat}");
            }
        }
    }

    #[test]
    fn case_fold() {
        let p = parse("ab", true).unwrap();
        if let Ast::Concat(v) = p.ast {
            if let Ast::Class(c) = &v[0] {
                assert!(c.contains(b'a') && c.contains(b'A'));
            } else {
                panic!();
            }
        } else {
            panic!();
        }
    }

    #[test]
    fn group_nonbinding() {
        let p = parse("(?:ab)+", false).unwrap();
        assert!(matches!(p.ast, Ast::Repeat { .. }));
        let p = parse("(ab)+", false).unwrap();
        assert!(matches!(p.ast, Ast::Repeat { .. }));
    }

    #[test]
    fn parse_errors() {
        for pat in [
            "(", ")", "a)", "[", "[]", "a{2", "a{5,2}", "*", "a**", r"\q", "a{99}",
            "a^b", "a$b",
        ] {
            assert!(parse(pat, false).is_err(), "{pat} should fail");
        }
    }

    #[test]
    fn empty_pattern_ok() {
        assert_eq!(parse("", false).unwrap().ast, Ast::Empty);
        assert!(matches!(parse("a|", false).unwrap().ast, Ast::Alt(_)));
    }

    #[test]
    fn byteclass_ops() {
        let mut c = ByteClass::empty();
        c.insert(b'a');
        assert!(c.contains(b'a'));
        c.remove(b'a');
        assert!(!c.contains(b'a'));
        let d = ByteClass::digit();
        let w = ByteClass::word();
        assert_eq!(d.union(&w), w);
        assert_eq!(d.iter().count(), 10);
    }
}
