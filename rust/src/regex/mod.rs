//! From-scratch regular-expression engine.
//!
//! SystemT's dominant extraction primitive is the regular expression
//! (paper Fig 4: up to 82 % of query runtime), and the paper's FPGA regex
//! matcher (their ref [20]) is a table-configured state machine streaming
//! one character per cycle. This module provides everything both execution
//! paths need, from scratch:
//!
//! * [`ast`] — the pattern syntax and parser (a practical subset: literals,
//!   classes, escapes, alternation, grouping, bounded/unbounded repetition,
//!   top-level anchors, case-insensitive flag);
//! * [`nfa`] — Thompson construction;
//! * [`dfa`] — subset construction to a dense byte-transition table, in two
//!   flavours: *anchored* (software matcher) and *search* (implicit `.*`
//!   prefix — the hardware match-end detector), plus the *reverse* DFA used
//!   to recover match starts from hardware-reported ends;
//! * [`matcher`] — the software all-matches semantics (leftmost-longest,
//!   non-overlapping) and the hardware-candidate reconstruction that must
//!   agree with it.
//!
//! The DFA transition tables are shared verbatim with the accelerator: the
//! Pallas kernel consumes exactly [`dfa::Dfa::table`] (padded), which is
//! what makes "reconfiguration" a data upload instead of a bitstream.

pub mod ast;
pub mod dfa;
pub mod matcher;
pub mod minimize;
pub mod nfa;

pub use ast::{parse, Ast, ByteClass, ParseError, Pattern};
pub use dfa::{Dfa, DfaKind, DEAD, START};
pub use matcher::{CompiledRegex, Match};
pub use minimize::minimize;

/// Compile a pattern string into a [`CompiledRegex`] (all three DFAs).
///
/// `case_insensitive` folds ASCII letters at parse time, matching SystemT's
/// `with flags 'CASE_INSENSITIVE'`.
pub fn compile(pattern: &str, case_insensitive: bool) -> Result<CompiledRegex, ParseError> {
    let pat = parse(pattern, case_insensitive)?;
    CompiledRegex::from_pattern(pat)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_compile_and_match() {
        let re = compile(r"[0-9]{3}-[0-9]{4}", false).unwrap();
        let ms = re.find_all("call 555-1234 or 555-9876.");
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].span.text("call 555-1234 or 555-9876."), "555-1234");
    }

    #[test]
    fn case_insensitive_flag() {
        let re = compile("ibm", true).unwrap();
        assert_eq!(re.find_all("IBM and ibm and IbM").len(), 3);
    }
}
