"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernel (interpret mode), the pure-jnp scan reference, and a
scalar python reference must agree bit-for-bit on the hit stream for
arbitrary valid tables/inputs. Hypothesis sweeps shapes, table contents
and byte streams.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dfa_scan import dfa_scan, START
from compile.kernels.ref import dfa_scan_ref, dfa_scan_py


def build_search_table(pattern: bytes, states_pad: int = 0):
    """Dense search-DFA table for a literal pattern (start-closure folded):
    mirrors the rust engine's Search DFA for a literal, written
    independently so the test is not circular.
    """
    n = len(pattern)
    S = n + 2  # dead, start, one per prefix consumed
    if states_pad:
        S = max(S, states_pad)
    table = np.zeros((S, 256), np.int32)

    def next_state(progress: int, byte: int) -> int:
        # longest suffix of consumed+byte that is a prefix of pattern
        consumed = pattern[:progress] + bytes([byte])
        for k in range(min(len(consumed), n), -1, -1):
            if k <= len(consumed) and consumed[-k:] == pattern[:k] and k <= n:
                if k == 0:
                    return 1
                return 1 + k
        return 1

    for progress in range(n + 1):
        s = 1 + progress
        for b in range(1, 256):
            table[s, b] = next_state(progress, b)
    table[:, 0] = START  # NUL separator resets every state
    table[0, 1:] = 0  # dead absorbs (unused for search tables)
    table[0, 0] = START
    accept = np.zeros(S, np.int32)
    accept[1 + n] = 1
    return table, accept


def run_all(bytes_np, tables_np, accepts_np):
    b = jnp.asarray(bytes_np, jnp.int32)
    t = jnp.asarray(tables_np, jnp.int32)
    a = jnp.asarray(accepts_np, jnp.int32)
    k = np.asarray(dfa_scan(b, t, a))
    r = np.asarray(dfa_scan_ref(b, t, a))
    return k, r


class TestLiteralPattern:
    def test_simple_hits(self):
        table, accept = build_search_table(b"ab")
        text = b"xxabyyab"
        bts = np.zeros((1, len(text)), np.int32)
        bts[0] = np.frombuffer(text, np.uint8)
        k, r = run_all(bts, table[None], accept[None])
        assert (k == r).all()
        ends = np.nonzero(k[0, 0])[0] + 1
        assert list(ends) == [4, 8]

    def test_nul_separator_blocks_match(self):
        table, accept = build_search_table(b"ab")
        text = b"a\x00b"
        bts = np.frombuffer(text, np.uint8).astype(np.int32)[None, :]
        k, _ = run_all(bts, table[None], accept[None])
        assert (k == 0).all()

    def test_multi_stream_independent(self):
        table, accept = build_search_table(b"ab")
        bts = np.zeros((4, 8), np.int32)
        bts[0, :2] = [ord("a"), ord("b")]
        bts[2, 3:5] = [ord("a"), ord("b")]
        k, r = run_all(bts, table[None], accept[None])
        assert (k == r).all()
        assert k[0, 0, 1] > 0
        assert k[0, 1].sum() == 0
        assert k[0, 2, 4] > 0
        assert k[0, 3].sum() == 0

    def test_multi_machine_parallel(self):
        t1, a1 = build_search_table(b"ab", states_pad=8)
        t2, a2 = build_search_table(b"ba", states_pad=8)
        tables = np.stack([t1, t2])
        accepts = np.stack([a1, a2])
        text = b"abba"
        bts = np.frombuffer(text, np.uint8).astype(np.int32)[None, :]
        k, r = run_all(bts, tables, accepts)
        assert (k == r).all()
        assert list(np.nonzero(k[0, 0])[0] + 1) == [2]  # 'ab' ends at 2
        assert list(np.nonzero(k[1, 0])[0] + 1) == [4]  # 'ba' ends at 4

    def test_padding_rows_inert(self):
        table, accept = build_search_table(b"ab", states_pad=64)
        text = b"abab"
        bts = np.frombuffer(text, np.uint8).astype(np.int32)[None, :]
        k, r = run_all(bts, table[None], accept[None])
        assert (k == r).all()
        assert (np.nonzero(k[0, 0])[0] + 1).tolist() == [2, 4]


@st.composite
def random_case(draw):
    machines = draw(st.integers(1, 3))
    states = draw(st.integers(2, 12))
    streams = draw(st.integers(1, 4))
    block = draw(st.integers(1, 64))
    # valid random tables: every entry is a valid state id; NUL column
    # resets to START per the layout contract
    table = draw(
        st.lists(
            st.lists(st.integers(0, states - 1), min_size=256, max_size=256),
            min_size=machines * states,
            max_size=machines * states,
        )
    )
    tables = np.array(table, np.int32).reshape(machines, states, 256)
    tables[:, :, 0] = START
    accepts = np.array(
        draw(
            st.lists(
                st.integers(0, 1),
                min_size=machines * states,
                max_size=machines * states,
            )
        ),
        np.int32,
    ).reshape(machines, states)
    bts = np.array(
        draw(
            st.lists(
                st.integers(0, 255),
                min_size=streams * block,
                max_size=streams * block,
            )
        ),
        np.int32,
    ).reshape(streams, block)
    return bts, tables, accepts


@settings(max_examples=40, deadline=None)
@given(random_case())
def test_kernel_equals_ref_random(case):
    bts, tables, accepts = case
    k, r = run_all(bts, tables, accepts)
    assert (k == r).all()


@settings(max_examples=15, deadline=None)
@given(random_case())
def test_fused_equals_grid_variant(case):
    """The production (fused) kernel and the TPU-tiling grid variant must
    agree bit-for-bit."""
    from compile.kernels.dfa_scan import dfa_scan_grid

    bts, tables, accepts = case
    b = jnp.asarray(bts, jnp.int32)
    t = jnp.asarray(tables, jnp.int32)
    a = jnp.asarray(accepts, jnp.int32)
    fused = np.asarray(dfa_scan(b, t, a))
    grid = np.asarray(dfa_scan_grid(b, t, a))
    assert (fused == grid).all()


@settings(max_examples=10, deadline=None)
@given(random_case())
def test_kernel_equals_scalar_py(case):
    bts, tables, accepts = case
    k, _ = run_all(bts, tables, accepts)
    for m in range(tables.shape[0]):
        py = dfa_scan_py(bts.tolist(), tables[m].tolist(), accepts[m].tolist())
        assert (k[m] == np.array(py, np.int32)).all()


class TestShapes:
    @pytest.mark.parametrize("machines,states", [(4, 64), (8, 128), (8, 256)])
    def test_artifact_geometries(self, machines, states):
        # every artifact geometry must run through the kernel
        tables = np.zeros((machines, states, 256), np.int32)
        tables[:, :, :] = START
        tables[:, :, 0] = START
        accepts = np.zeros((machines, states), np.int32)
        bts = np.zeros((4, 128), np.int32)
        k, r = run_all(bts, tables, accepts)
        assert k.shape == (machines, 4, 128)
        assert (k == r).all()

    def test_hits_dtype_and_range(self):
        table, accept = build_search_table(b"q")
        bts = np.full((2, 32), ord("q"), np.int32)
        k, _ = run_all(bts, table[None], accept[None])
        assert k.dtype == np.int32
        assert k.max() < table.shape[0]
        assert (k >= 0).all()
