"""L2 model tests: the extract_package graph (kernel + count reduction)."""

import numpy as np
import jax.numpy as jnp

from compile.model import extract_package
from tests.test_kernel import build_search_table


def test_counts_match_hits():
    table, accept = build_search_table(b"ab")
    text = b"ababab"
    bts = np.zeros((4, 16), np.int32)
    bts[0, : len(text)] = np.frombuffer(text, np.uint8)
    bts[3, : len(text)] = np.frombuffer(text, np.uint8)
    hits, counts = extract_package(
        jnp.asarray(bts), jnp.asarray(table[None]), jnp.asarray(accept[None])
    )
    hits, counts = np.asarray(hits), np.asarray(counts)
    assert counts.shape == (1, 4)
    assert counts[0, 0] == 3
    assert counts[0, 1] == 0
    assert counts[0, 3] == 3
    assert (counts == (hits > 0).sum(-1)).all()


def test_empty_package():
    table, accept = build_search_table(b"xy")
    bts = np.zeros((4, 8), np.int32)
    hits, counts = extract_package(
        jnp.asarray(bts), jnp.asarray(table[None]), jnp.asarray(accept[None])
    )
    assert np.asarray(hits).sum() == 0
    assert np.asarray(counts).sum() == 0
