"""AOT tests: lowering produces loadable HLO text; caching works."""

import os
import subprocess
import sys

from compile.aot import (
    BLOCK_SIZES,
    GEOMETRIES,
    STREAMS,
    artifact_name,
    lower_variant,
    source_digest,
)


def test_lower_smallest_variant_produces_hlo_text():
    text = lower_variant(4, 64, 256)
    assert "HloModule" in text
    assert "while" in text.lower()  # the fori_loop scan survives lowering
    # parameters: bytes, tables, accepts
    assert text.count("parameter(") >= 3


def test_artifact_names_match_rust_convention():
    assert artifact_name(8, 256, 4096) == "dfa_m8_s256_b4096.hlo.txt"


def test_menu_matches_rust_side():
    # parse GEOMETRIES/BLOCK_SIZES straight out of the rust source so the
    # two menus cannot drift apart silently
    here = os.path.dirname(os.path.abspath(__file__))
    rust_src = os.path.join(here, "..", "..", "rust", "src", "hwcompiler", "mod.rs")
    with open(rust_src) as f:
        src = f.read()
    for (m, s) in GEOMETRIES:
        assert f"({m}, {s})" in src, f"geometry ({m},{s}) missing from rust menu"
    for b in BLOCK_SIZES:
        assert str(b) in src
    assert f"pub const STREAMS: usize = {STREAMS};" in src


def test_digest_changes_with_source():
    d1 = source_digest()
    assert len(d1) == 64
    d2 = source_digest()
    assert d1 == d2  # stable


def test_cached_run_is_noop(tmp_path):
    out = str(tmp_path)
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    pkg_root = os.path.join(here, "..")
    # first run writes, second run is a no-op
    r1 = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out],
        cwd=pkg_root,
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r1.returncode == 0, r1.stderr
    assert "wrote" in r1.stdout
    r2 = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out],
        cwd=pkg_root,
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r2.returncode == 0, r2.stderr
    assert "up to date" in r2.stdout
