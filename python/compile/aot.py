"""AOT: lower the L2 graph to HLO text artifacts for the rust runtime.

One artifact per (machines, states, block) variant — the menu must match
``GEOMETRIES``/``BLOCK_SIZES`` in ``rust/src/hwcompiler/mod.rs``. The rust
runtime loads ``artifacts/dfa_m{M}_s{S}_b{B}.hlo.txt`` via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

HLO *text* is the interchange format, not ``.serialize()``: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).

Python runs only here, at build time — never on the request path.
``make artifacts`` re-runs this only when the python sources change.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import extract_package

# Keep in sync with rust/src/hwcompiler/mod.rs (GEOMETRIES, BLOCK_SIZES,
# STREAMS). The rust side checks artifact presence by file name.
# The wide (16/32-machine) variants serve the multi-query catalog: all
# deployed queries' deduplicated extraction leaves fold into one image.
GEOMETRIES = [(4, 64), (8, 128), (8, 256), (4, 1024), (16, 256), (16, 1024), (32, 1024)]
BLOCK_SIZES = [4096, 16384]
STREAMS = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(machines: int, states: int, block: int) -> str:
    bytes_spec = jax.ShapeDtypeStruct((STREAMS, block), jnp.int32)
    tables_spec = jax.ShapeDtypeStruct((machines, states, 256), jnp.int32)
    accepts_spec = jax.ShapeDtypeStruct((machines, states), jnp.int32)
    lowered = jax.jit(extract_package).lower(bytes_spec, tables_spec, accepts_spec)
    return to_hlo_text(lowered)


def artifact_name(machines: int, states: int, block: int) -> str:
    return f"dfa_m{machines}_s{states}_b{block}.hlo.txt"


def source_digest() -> str:
    """Digest of the python sources that determine artifact content."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for rel in ("aot.py", "model.py", "kernels/dfa_scan.py"):
        with open(os.path.join(here, rel), "rb") as f:
            h.update(f.read())
    h.update(jax.__version__.encode())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    stamp_path = os.path.join(args.out_dir, "SOURCES.sha256")
    digest = source_digest()
    expected = [artifact_name(m, s, b) for (m, s) in GEOMETRIES for b in BLOCK_SIZES]
    if not args.force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == digest and all(
                os.path.exists(os.path.join(args.out_dir, n)) for n in expected
            ):
                print(f"artifacts up to date ({len(expected)} variants)")
                return 0

    for (machines, states) in GEOMETRIES:
        for block in BLOCK_SIZES:
            name = artifact_name(machines, states, block)
            path = os.path.join(args.out_dir, name)
            text = lower_variant(machines, states, block)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text) / 1024:.0f} KiB)")
    with open(stamp_path, "w") as f:
        f.write(digest)
    print(f"{len(expected)} artifacts in {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
