"""Pure-jnp oracle for the DFA scan kernel.

Same contract as :func:`dfa_scan.dfa_scan`, implemented as a
``lax.scan`` over byte positions with vectorized machine/stream state.
This is the correctness reference every kernel change is tested against
(and the "pure-jnp roofline" baseline for the L1 performance target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

START = 1


def dfa_scan_ref(bytes_i32, tables, accepts):
    """Reference implementation.

    Args:
      bytes_i32: int32[streams, block]
      tables:    int32[machines, states, 256]
      accepts:   int32[machines, states]

    Returns:
      int32[machines, streams, block]
    """
    machines, _, _ = tables.shape
    streams, _ = bytes_i32.shape

    def step(state, b):
        # state: [machines, streams]; b: [streams]
        # next[m, s] = tables[m, state[m, s], b[s]]
        rows = jnp.take_along_axis(tables, state[:, :, None], axis=1)  # [M, streams, 256]
        cols = jnp.broadcast_to(b[None, :, None].astype(jnp.int32), (machines, streams, 1))
        next_state = jnp.take_along_axis(rows, cols, axis=2)[:, :, 0]
        acc = jnp.take_along_axis(accepts, next_state, axis=1)
        hit = jnp.where(acc > 0, next_state, 0)
        return next_state, hit

    init = jnp.full((machines, streams), START, jnp.int32)
    _, hits = jax.lax.scan(step, init, bytes_i32.T)  # scan over block axis
    # hits: [block, machines, streams] -> [machines, streams, block]
    return jnp.transpose(hits, (1, 2, 0))


def dfa_scan_py(bytes_rows, table, accept):
    """Plain-python single-machine scalar reference (for tiny cases and
    debugging; exercised by the pytest suite against both jnp paths).

    Args:
      bytes_rows: list[list[int]]  per-stream byte values
      table: list of S rows x 256 next-state entries
      accept: list[int] of length S

    Returns:
      list[list[int]] hit stream per stream row.
    """
    out = []
    for row in bytes_rows:
        state = START
        hits = []
        for b in row:
            state = table[state][b]
            hits.append(state if accept[state] else 0)
        out.append(hits)
    return out
