"""L1: the multi-machine DFA byte-scan Pallas kernel.

This is the accelerator datapath of the paper: a table-configured
multi-pattern matcher streaming document bytes. On the Stratix IV each
pattern machine was a BRAM-resident state table consuming one character
per cycle per stream; here the per-byte recurrence is a sequential scan
carrying the `[machines, streams]` state matrix, with the transition
tables resident in VMEM.

Layout contract (shared with `rust/src/hwcompiler`):

* ``bytes``   int32[streams, block]   values 0..255; 0 = NUL is the
  work-package document separator (every table row maps 0 -> START)
* ``tables``  int32[machines, states, 256]  next-state tables
  (state 0 = dead, 1 = start)
* ``accepts`` int32[machines, states]  0/1 accept flags
* output      int32[machines, streams, block]  state id if the state
  reached *after* consuming byte [s, i] accepts, else 0

Two kernels:

* :func:`dfa_scan` — the production kernel: ONE grid step, the state
  matrix vectorized over machines x streams, `lax.scan` along the byte
  axis. Per-byte work is a 2-D gather from the VMEM-resident tables —
  on TPU this maps to VPU lanes over the (machines, streams) tile; under
  interpret=True it executes ~8x fewer sequential loop iterations than
  the per-machine grid variant (see EXPERIMENTS.md §Perf L1).
* :func:`dfa_scan_grid` — the per-machine grid variant whose BlockSpecs
  express the HBM->VMEM tiling a real TPU would use when the combined
  tables exceed VMEM (one machine's `[states, 256]` table per grid step).
  Kept as a compile-only reference and cross-checked in pytest.

Pallas is lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is estimated from the
VMEM footprint in DESIGN.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

START = 1


def _dfa_scan_fused_kernel(bytes_ref, table_ref, accept_ref, out_ref):
    """All machines and streams in one kernel instance.

    bytes_ref:  [streams, block]
    table_ref:  [machines, states, 256]
    accept_ref: [machines, states]
    out_ref:    [machines, streams, block]
    """
    machines = table_ref.shape[0]
    streams = bytes_ref.shape[0]

    table = table_ref[...]  # VMEM-resident for our geometries (<=4 MiB)
    accept = accept_ref[...]
    bytes_t = bytes_ref[...].T  # [block, streams]

    m_idx = jnp.arange(machines, dtype=jnp.int32)[:, None]  # [M, 1]

    def step(state, b):
        # state: [machines, streams]; b: [streams]
        next_state = table[m_idx, state, b[None, :]]
        hit = jnp.where(accept[m_idx, next_state] > 0, next_state, 0)
        return next_state, hit

    init = jnp.full((machines, streams), START, jnp.int32)
    _, hits = jax.lax.scan(step, init, bytes_t)  # hits: [block, M, streams]
    out_ref[...] = jnp.transpose(hits, (1, 2, 0))


def dfa_scan(bytes_i32, tables, accepts):
    """Run every machine over the byte block (production kernel).

    Args:
      bytes_i32: int32[streams, block]
      tables:    int32[machines, states, 256]
      accepts:   int32[machines, states]

    Returns:
      int32[machines, streams, block] hit stream (accepting state or 0).
    """
    machines, _, _ = tables.shape
    streams, block = bytes_i32.shape
    return pl.pallas_call(
        _dfa_scan_fused_kernel,
        out_shape=jax.ShapeDtypeStruct((machines, streams, block), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(bytes_i32, tables, accepts)


def _dfa_scan_grid_kernel(bytes_ref, table_ref, accept_ref, out_ref):
    """One machine (grid step) over all streams — the TPU-tiling variant."""
    streams = bytes_ref.shape[0]
    block = bytes_ref.shape[1]

    def step(i, state):
        b = bytes_ref[:, i]  # [streams]
        state = table_ref[state, b]
        hit = jnp.where(accept_ref[state] > 0, state, 0)
        out_ref[:, i] = hit
        return state

    jax.lax.fori_loop(0, block, step, jnp.full((streams,), START, jnp.int32))


def dfa_scan_grid(bytes_i32, tables, accepts):
    """Per-machine grid variant (BlockSpec tiling reference; slower under
    interpret mode — see module docs)."""
    machines, states, _ = tables.shape
    streams, block = bytes_i32.shape
    return pl.pallas_call(
        _dfa_scan_grid_kernel,
        grid=(machines,),
        in_specs=[
            pl.BlockSpec((streams, block), lambda m: (0, 0)),
            pl.BlockSpec((None, states, 256), lambda m: (m, 0, 0)),
            pl.BlockSpec((None, states), lambda m: (m, 0)),
        ],
        out_specs=pl.BlockSpec((None, streams, block), lambda m: (m, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((machines, streams, block), jnp.int32),
        interpret=True,
    )(bytes_i32, tables, accepts)
