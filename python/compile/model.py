"""L2: the accelerated-subgraph compute graph.

The JAX function the rust runtime executes per work package: the Pallas
DFA scan (L1) plus the reductions the coordinator wants alongside the raw
hit stream — per-(machine, stream) hit counts, so the post-stage can skip
machines/streams with no matches without touching the hit tensor.

This is the whole of the paper's on-FPGA dataflow: extraction machines in
parallel over the byte streams, followed by lightweight aggregation; the
relational operators of an offloaded subgraph run in the accelerator
service's post-stage at modeled hardware rates (see
``rust/src/accel``/``rust/src/perfmodel``).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.dfa_scan import dfa_scan


def extract_package(bytes_i32, tables, accepts):
    """Process one work package.

    Args:
      bytes_i32: int32[streams, block] byte values (0 = separator/padding)
      tables:    int32[machines, states, 256]
      accepts:   int32[machines, states]

    Returns:
      (hits, counts):
        hits   int32[machines, streams, block] — accepting state or 0 at
               every byte position (the FPGA's match-event stream);
        counts int32[machines, streams] — number of hits, so the host can
               skip empty (machine, stream) pairs without reading `hits`.
    """
    hits = dfa_scan(bytes_i32, tables, accepts)
    counts = jnp.sum((hits > 0).astype(jnp.int32), axis=-1)
    return hits, counts
